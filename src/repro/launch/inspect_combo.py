import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""Hillclimb profiling tool: compile one (arch x shape x variant) combo and
print the trip-count-weighted collective breakdown + biggest dots.

    PYTHONPATH=src python -m repro.launch.inspect_combo --arch qwen3-moe-30b-a3b \
        --shape train_4k [--variant baseline] [--multi-pod] [--top 15]
"""
import argparse

import jax

from repro.configs.registry import get_arch, get_shape
from repro.launch import shardings as sh
from repro.launch.dryrun import VARIANTS
from repro.launch.hlo_analysis import analyze, parse_hlo, _bytes_of, _TRIP_RE
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build
from repro.sharding_ctx import activation_sharding


def compile_combo(arch: str, shape_name: str, variant: str = "baseline",
                  multi_pod: bool = False):
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    vkw = dict(VARIANTS.get(variant, {}))
    data_sz = vkw.pop("mesh_data", 16)
    model_sz = vkw.pop("mesh_model", 16)
    mesh = make_production_mesh(multi_pod=multi_pod, data=data_sz,
                                model=model_sz)
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    pol = sh.ShardingPolicy(batch_axes=batch_axes, **vkw)
    built = build(cfg, shape, mesh, pol, remat=(variant != "no_remat"))
    batch_ok = shape.global_batch % sh._axis_size(mesh, batch_axes) == 0
    with mesh, activation_sharding(batch_axes, "model",
                                   batch_shardable=batch_ok, mesh=mesh,
                                   fsdp_axis="data" if pol.fsdp else None):
        compiled = jax.jit(
            built["fn"],
            in_shardings=sh.to_named(mesh, built["in_shardings"]),
            out_shardings=sh.to_named(mesh, built["out_shardings"]),
        ).lower(*built["args"]).compile()
    return compiled


def breakdown(hlo: str, top: int = 15):
    comps = parse_hlo(hlo)
    # multipliers from the analyzer's walk
    res = analyze(hlo)
    # re-walk to get per-op weighted rows
    import re
    from collections import defaultdict
    mult = defaultdict(lambda: 1.0)
    # reconstruct multiplier map (analyze doesn't export it; recompute)
    entry_m = re.search(r"^ENTRY\s+(%?[\w.\-]+)", hlo, re.M)
    entry = entry_m.group(1) if entry_m else next(iter(comps))
    seen = {}

    def visit(comp, m_in):
        if comp not in comps or seen.get(comp, 0) >= m_in:
            return
        seen[comp] = m_in
        for op in comps[comp]:
            trip = 1
            tm = _TRIP_RE.search(op.rhs)
            if tm:
                trip = int(tm.group(1))
            for t in re.findall(
                    r"(?:body|condition|calls|to_apply)=(%[\w.\-]+)", op.rhs):
                visit(t, m_in * (trip if f"body={t}" in op.rhs
                                 and op.opcode == "while" else 1))

    visit(entry, 1.0)

    rows = []
    for cname, ops in comps.items():
        m_ = seen.get(cname, 0)
        if not m_:
            continue
        for op in ops:
            if op.opcode in ("all-gather", "all-reduce", "reduce-scatter",
                             "all-to-all", "collective-permute"):
                b = (_bytes_of(op.dtype, op.dims) if op.dims
                     else sum(_bytes_of(d, s) for d, s in op.tuple_shapes))
                meta = re.search(r'op_name="([^"]*)"', op.rhs)
                rows.append((b * m_, b, m_, op.opcode, op.dtype or "tuple",
                             str(op.dims or [t[1] for t in op.tuple_shapes])[:38],
                             (meta.group(1)[-58:] if meta else "")))
    rows.sort(reverse=True)
    return res, rows[:top]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=15)
    args = ap.parse_args()

    compiled = compile_combo(args.arch, args.shape, args.variant,
                             args.multi_pod)
    res, rows = breakdown(compiled.as_text(), args.top)
    print(f"\n{args.arch} x {args.shape} x {args.variant}")
    print(f"flops/dev {res['flops_corrected']/1e12:.2f} TF | "
          f"collective {res['collective_bytes_total']/1e9:.1f} GB/dev")
    print(f"{'GB(w)':>8} {'MB(1)':>9} {'x':>5}  {'op':<18} {'dt':<5} "
          f"{'shape':<38} op_name")
    for w, b, m_, opn, dt, dims, meta in rows:
        print(f"{w/1e9:>8.1f} {b/1e6:>9.1f} {m_:>5.0f}  {opn:<18} {dt:<5} "
              f"{dims:<38} {meta}")


if __name__ == "__main__":
    main()
