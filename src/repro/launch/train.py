"""Training driver.

CPU-scale (default): trains a reduced variant of any assigned architecture
on the synthetic Markov token stream, with checkpointing and logging —
the end-to-end path a real run would take.

Production-scale flags mirror the dry-run: ``--preset full`` lowers the full
config against the production mesh (requires the 512-device XLA flag, i.e.
run dryrun.py instead for analysis; on real hardware this is the entry
point).

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-1.3b --steps 50
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save, restore, latest_step
from repro.configs.base import reduced
from repro.configs.registry import get_arch
from repro.data.tokens import MarkovTokens
from repro.models import Model
from repro.optim import adamw, cosine_schedule


def train_loop(cfg, *, steps: int, batch: int, seq: int, lr: float,
               ckpt_dir=None, ckpt_every: int = 0, seed: int = 0,
               log_every: int = 10):
    model = Model(cfg)
    key = jax.random.key(seed)
    params = model.init(key)
    opt = adamw(cosine_schedule(lr, max(steps // 20, 1), steps), b2=0.95,
                weight_decay=0.01)
    opt_state = opt.init(params)
    step0 = 0
    if ckpt_dir and latest_step(ckpt_dir) is not None:
        (params, opt_state), step0, _ = restore(
            ckpt_dir, (params, opt_state))
        print(f"resumed from step {step0}")

    @jax.jit
    def train_step(params, opt_state, step, batch_):
        (loss, mets), grads = jax.value_and_grad(
            lambda p: model.loss(p, batch_), has_aux=True)(params)
        new_params, new_opt = opt.update(grads, params, opt_state, step)
        return new_params, new_opt, loss, mets

    stream = MarkovTokens(cfg.vocab_size, seed=seed)
    losses = []
    t0 = time.time()
    for i, b in enumerate(stream.batches(batch, seq, steps - step0)):
        step = step0 + i
        jb = {k: jnp.asarray(v) for k, v in b.items()}
        if cfg.frontend == "audio":
            jb["frames"] = jnp.zeros(
                (batch, cfg.encoder_seq, cfg.d_model), jnp.float32)
        params, opt_state, loss, mets = train_step(
            params, opt_state, jnp.asarray(step), jb)
        losses.append(float(loss))
        if step % log_every == 0 or step == steps - 1:
            dt = time.time() - t0
            print(f"step {step:5d}  loss {float(loss):7.4f}  "
                  f"ce {float(mets['ce']):7.4f}  {dt:6.1f}s", flush=True)
        if ckpt_dir and ckpt_every and (step + 1) % ckpt_every == 0:
            save(ckpt_dir, step + 1, (params, opt_state))
    if ckpt_dir:
        save(ckpt_dir, steps, (params, opt_state))
    return params, losses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--preset", choices=["smoke", "full"], default="smoke")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.preset == "smoke":
        cfg = reduced(cfg, n_layers=args.layers, d_model=args.d_model)
    print(f"training {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{args.steps} steps of {args.batch}x{args.seq}")
    _, losses = train_loop(cfg, steps=args.steps, batch=args.batch,
                           seq=args.seq, lr=args.lr, ckpt_dir=args.ckpt_dir,
                           ckpt_every=args.ckpt_every, seed=args.seed)
    print(f"first-10 mean loss {np.mean(losses[:10]):.4f} -> "
          f"last-10 mean loss {np.mean(losses[-10:]):.4f}")


if __name__ == "__main__":
    main()
