# Pallas TPU kernels for the framework's compute hot-spots.
# <name>.py: pl.pallas_call + BlockSpec; ref.py: pure-jnp oracles asserted
# in tests; dispatch.py: backend selection (interpret / Mosaic / XLA
# fallback) with shape-bucketed autotuning; ops.py: the public entry
# points, all routed through the dispatcher.
from repro.kernels.dispatch import (  # noqa: F401
    BACKENDS, KernelPolicy, available_backends, bucket_of, default_policy,
    set_default_policy)
from repro.kernels.ops import (  # noqa: F401
    stump_scan, ensemble_vote, ensemble_vote_batched, stump_vote_batched,
    flash_attention, dist_update)
