# Pallas TPU kernels for the framework's compute hot-spots.
# <name>.py: pl.pallas_call + BlockSpec; ops.py: jit'd wrappers (padding,
# interpret-mode selection); ref.py: pure-jnp oracles asserted in tests.
from repro.kernels.ops import (  # noqa: F401
    stump_scan, ensemble_vote, ensemble_vote_batched, stump_vote_batched,
    flash_attention)
