"""Jit'd public wrappers for the Pallas kernels: padding to hardware-aligned
block shapes, dtype handling, interpret-mode selection (CPU containers run
the kernels in interpret mode; on a real TPU backend `interpret=False`
compiles them to Mosaic).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.stump_scan import stump_scan_kernel
from repro.kernels.ensemble_vote import (
    ensemble_vote_kernel, ensemble_vote_batched_kernel,
    stump_vote_batched_kernel)
from repro.kernels.flash_attention import flash_attention_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jnp.ndarray, axis: int, mult: int, value=0.0) -> jnp.ndarray:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def stump_scan(x: jnp.ndarray, y: jnp.ndarray, w: jnp.ndarray,
               thresholds: jnp.ndarray, *, block_n: int = 256,
               interpret: bool | None = None) -> jnp.ndarray:
    """Weighted stump errors over the (F, T) grid.  Pads N to block_n with
    zero-weight rows (no contribution) and F to the 8-sublane boundary."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    N, F = x.shape
    T = thresholds.shape[1]
    xp = _pad_to(x, 0, block_n)
    yp = _pad_to(y, 0, block_n, value=1.0)
    wp = _pad_to(w, 0, block_n, value=0.0)
    xp = _pad_to(xp, 1, 8)
    thr = _pad_to(_pad_to(thresholds, 0, 8, value=jnp.inf), 1, 8,
                  value=jnp.inf)
    err = stump_scan_kernel(xp, yp, wp, thr, block_n=block_n,
                            interpret=interpret)
    return err[:F, :T]


def ensemble_vote(margins: jnp.ndarray, alphas: jnp.ndarray, *,
                  block_t: int = 128, block_n: int = 512,
                  interpret: bool | None = None) -> jnp.ndarray:
    """H margins = sum_t alpha_t h_t.  Pads T with zero-alpha rows and N
    with dummy columns."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    T, N = margins.shape
    bt, bn = _vote_blocks(T, N, block_t, block_n)
    mp = _pad_to(_pad_to(margins, 0, bt), 1, bn)
    ap = _pad_to(alphas, 0, bt, value=0.0)
    out = ensemble_vote_kernel(mp, ap, block_t=bt, block_n=bn,
                               interpret=interpret)
    return out[:N]


def _vote_blocks(T: int, N: int, block_t: int, block_n: int):
    bt = min(block_t, max(8, 1 << (max(T, 1) - 1).bit_length()))
    bn = min(block_n, max(128, 1 << (max(N, 1) - 1).bit_length()))
    return bt, bn


def ensemble_vote_batched(margins: jnp.ndarray, alphas: jnp.ndarray, *,
                          block_t: int = 128, block_n: int = 512,
                          interpret: bool | None = None) -> jnp.ndarray:
    """Per-tenant H margins for packed serving batches.

    margins: (B,T,N); alphas: (B,T) -> (B,N).  Pads T with zero-alpha rows
    and N with dummy columns (sliced off)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    B, T, N = margins.shape
    bt, bn = _vote_blocks(T, N, block_t, block_n)
    mp = _pad_to(_pad_to(margins, 1, bt), 2, bn)
    ap = _pad_to(alphas, 1, bt, value=0.0)
    out = ensemble_vote_batched_kernel(mp, ap, block_t=bt, block_n=bn,
                                       interpret=interpret)
    return out[:, :N]


def stump_vote_batched(xsel: jnp.ndarray, thr: jnp.ndarray, pol: jnp.ndarray,
                       alphas: jnp.ndarray, *, block_t: int = 128,
                       block_n: int = 512,
                       interpret: bool | None = None) -> jnp.ndarray:
    """Fused stump-margin + weighted-vote for packed serving batches.

    xsel: (B,T,N) gathered features; thr/pol/alphas: (B,T) -> (B,N).
    Pads T with zero-alpha rows (thr/pol padding is irrelevant: alpha=0
    nullifies the row) and N with dummy columns."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    B, T, N = xsel.shape
    bt, bn = _vote_blocks(T, N, block_t, block_n)
    xp = _pad_to(_pad_to(xsel, 1, bt), 2, bn)
    tp = _pad_to(thr, 1, bt, value=0.0)
    pp = _pad_to(pol, 1, bt, value=1.0)
    ap = _pad_to(alphas, 1, bt, value=0.0)
    out = stump_vote_batched_kernel(xp, tp, pp, ap, block_t=bt, block_n=bn,
                                    interpret=interpret)
    return out[:, :N]


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128,
                    interpret: bool | None = None) -> jnp.ndarray:
    """q,k,v: (B,H,T,d) -> (B,H,T,d).  Pads T to the block boundary (extra
    keys masked out by causality / zero value) and d to 128 lanes."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    B, H, T, d = q.shape
    bq = min(block_q, T) if T % min(block_q, T) == 0 else T
    bk = min(block_k, T) if T % min(block_k, T) == 0 else T
    qf = q.reshape(B * H, T, d)
    kf = k.reshape(B * H, T, d)
    vf = v.reshape(B * H, T, d)
    dp = (-d) % 128
    if dp:
        # zero-pad head_dim: extra lanes contribute 0 to q.k and to output
        qf = _pad_to(qf, 2, 128)
        kf = _pad_to(kf, 2, 128)
        vf = _pad_to(vf, 2, 128)
    # NOTE: the kernel scales by 1/sqrt(d_padded); pre-scale q so the
    # effective scale reflects the true head_dim
    if dp:
        qf = qf * (((d + dp) ** 0.5) / (d ** 0.5))
    out = flash_attention_kernel(
        qf, kf, vf, causal=causal, block_q=bq, block_k=bk,
        interpret=interpret)
    out = out[..., :d]
    return out.reshape(B, H, T, d)


def dist_update(alpha, D, y, h, *, block_n: int = 1024,
                interpret: bool | None = None):
    """Fused AdaBoost distribution update -> (D_normalized, Z).
    Pads N with zero-mass rows (no contribution to Z)."""
    from repro.kernels.dist_update import dist_update_kernel
    interpret = (not _on_tpu()) if interpret is None else interpret
    N = D.shape[0]
    bn = min(block_n, max(256, 1 << (N - 1).bit_length()))
    Dp = _pad_to(D, 0, bn, value=0.0)
    yp = _pad_to(y, 0, bn, value=1.0)
    hp = _pad_to(h, 0, bn, value=0.0)
    w, Z = dist_update_kernel(jnp.asarray(alpha, jnp.float32), Dp, yp, hp,
                              block_n=bn, interpret=interpret)
    return (w / (Z[0] + 1e-30))[:N], Z[0]
