"""Public kernel entry points, routed through the backend dispatcher.

The padding/dtype handling for the Pallas substrates and the pure-XLA
fallback live together in :mod:`repro.kernels.dispatch`; each wrapper here
names the kernel, forwards its block-shape hints, and exposes the common
selection surface:

* ``backend=``   one-call override: ``"interpret"`` | ``"mosaic"`` | ``"xla"``
* ``policy=``    a :class:`~repro.kernels.dispatch.KernelPolicy` (forced
                 backend and/or calibration table)
* ``interpret=`` deprecated bool shim (True -> "interpret", False ->
                 "mosaic"); warns and will be removed next release

Block-shape kwargs (``block_t``/``block_n``/``block_q``/``block_k``)
default to ``None``, which means "let the calibration table decide": the
dispatcher injects the tuned layout recorded for the resolved (kernel,
shape-bucket, backend) — or the hardcoded reference layout when nothing is
tuned.  Passing an explicit int always wins over both.

With no backend selection, the process-default policy re-resolves on every
call: ``REPRO_KERNEL_BACKEND`` env var > calibration table > platform
default (Mosaic on TPU, interpret elsewhere).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.dispatch import KernelPolicy  # noqa: F401  (re-export)

# back-compat aliases for the helpers that used to live here
_pad_to = dispatch.pad_to
_on_tpu = dispatch.on_tpu
_vote_blocks = dispatch.vote_blocks


def stump_scan(x: jnp.ndarray, y: jnp.ndarray, w: jnp.ndarray,
               thresholds: jnp.ndarray, *, block_n: Optional[int] = None,
               backend: Optional[str] = None,
               policy: Optional[KernelPolicy] = None,
               interpret: Optional[bool] = None) -> jnp.ndarray:
    """Weighted stump errors over the (F, T) grid.  Pallas substrates pad N
    to block_n with zero-weight rows (no contribution) and F/T to the
    8-sublane boundary."""
    return dispatch.dispatch(
        "stump_scan", (x, y, w, thresholds), dict(block_n=block_n),
        policy=policy, backend=backend, interpret=interpret)


def stump_scan_batched(x: jnp.ndarray, y: jnp.ndarray, w: jnp.ndarray,
                       thresholds: jnp.ndarray, *,
                       block_n: Optional[int] = None,
                       backend: Optional[str] = None,
                       policy: Optional[KernelPolicy] = None,
                       interpret: Optional[bool] = None) -> jnp.ndarray:
    """Per-client weighted stump errors for a stacked fleet batch.

    x: (B,N,F); y, w: (B,N); thresholds: (B,F,T) -> (B,F,T).  Same padding
    contract as :func:`stump_scan` per batch slot (w = 0 rows contribute
    nothing, so ragged shards stack safely); B lifts onto the launch grid."""
    return dispatch.dispatch(
        "stump_scan_batched", (x, y, w, thresholds), dict(block_n=block_n),
        policy=policy, backend=backend, interpret=interpret)


def ensemble_vote(margins: jnp.ndarray, alphas: jnp.ndarray, *,
                  block_t: Optional[int] = None,
                  block_n: Optional[int] = None,
                  backend: Optional[str] = None,
                  policy: Optional[KernelPolicy] = None,
                  interpret: Optional[bool] = None) -> jnp.ndarray:
    """H margins = sum_t alpha_t h_t.  Pallas substrates pad T with
    zero-alpha rows and N with dummy columns (sliced off)."""
    return dispatch.dispatch(
        "ensemble_vote", (margins, alphas),
        dict(block_t=block_t, block_n=block_n),
        policy=policy, backend=backend, interpret=interpret)


def ensemble_vote_batched(margins: jnp.ndarray, alphas: jnp.ndarray, *,
                          block_t: Optional[int] = None,
                          block_n: Optional[int] = None,
                          backend: Optional[str] = None,
                          policy: Optional[KernelPolicy] = None,
                          interpret: Optional[bool] = None) -> jnp.ndarray:
    """Per-tenant H margins for packed serving batches.

    margins: (B,T,N); alphas: (B,T) -> (B,N).  Same padding contract as
    :func:`ensemble_vote`, per batch slot."""
    return dispatch.dispatch(
        "ensemble_vote_batched", (margins, alphas),
        dict(block_t=block_t, block_n=block_n),
        policy=policy, backend=backend, interpret=interpret)


def stump_vote_batched(xsel: jnp.ndarray, thr: jnp.ndarray, pol: jnp.ndarray,
                       alphas: jnp.ndarray, *,
                       block_t: Optional[int] = None,
                       block_n: Optional[int] = None,
                       backend: Optional[str] = None,
                       policy: Optional[KernelPolicy] = None,
                       interpret: Optional[bool] = None) -> jnp.ndarray:
    """Fused stump-margin + weighted-vote for packed serving batches.

    xsel: (B,T,N) gathered features; thr/pol/alphas: (B,T) -> (B,N).
    Zero-alpha padding rows nullify whatever thr/pol padding holds."""
    return dispatch.dispatch(
        "stump_vote_batched", (xsel, thr, pol, alphas),
        dict(block_t=block_t, block_n=block_n),
        policy=policy, backend=backend, interpret=interpret)


def stump_vote_fp_batched(xsel: jnp.ndarray, thr: jnp.ndarray,
                          pol: jnp.ndarray, alphas: jnp.ndarray, *,
                          block_t: Optional[int] = None,
                          block_n: Optional[int] = None,
                          backend: Optional[str] = None,
                          policy: Optional[KernelPolicy] = None,
                          interpret: Optional[bool] = None):
    """One-launch serving path: fused stump-margin + weighted-vote + xor-fold
    feature fingerprint.

    Same contract as :func:`stump_vote_batched`, returning ``(margins
    (B,N) f32, fp0 (B,N) u32, fp1 (B,N) u32)``.  The fingerprint lanes are
    exact integers, identical across backends, block layouts, and T/N
    padding (zero-alpha rows are the XOR identity), so
    ``serve.engine.BatchEvaluator`` can key its result cache on them
    without re-hashing any feature vector on the host."""
    return dispatch.dispatch(
        "stump_vote_fp_batched", (xsel, thr, pol, alphas),
        dict(block_t=block_t, block_n=block_n),
        policy=policy, backend=backend, interpret=interpret)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, block_q: Optional[int] = None,
                    block_k: Optional[int] = None,
                    backend: Optional[str] = None,
                    policy: Optional[KernelPolicy] = None,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """q,k,v: (B,H,T,d) -> (B,H,T,d).  Pallas substrates pad d to 128 lanes
    (with a q pre-scale correcting the kernel's 1/sqrt(d_padded))."""
    return dispatch.dispatch(
        "flash_attention", (q, k, v),
        dict(causal=causal, block_q=block_q, block_k=block_k),
        policy=policy, backend=backend, interpret=interpret)


def dist_update(alpha, D, y, h, *, block_n: Optional[int] = None,
                backend: Optional[str] = None,
                policy: Optional[KernelPolicy] = None,
                interpret: Optional[bool] = None):
    """Fused AdaBoost distribution update -> (D_normalized, Z).
    Pallas substrates pad N with zero-mass rows (no contribution to Z)."""
    return dispatch.dispatch(
        "dist_update", (alpha, D, y, h), dict(block_n=block_n),
        policy=policy, backend=backend, interpret=interpret)
