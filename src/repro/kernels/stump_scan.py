"""Pallas TPU kernel: AdaBoost weighted-error sweep over the (feature x
threshold) stump grid — the compute hot-spot of every boosting round.

TPU adaptation (DESIGN.md §4): instead of the GPU one-thread-per-threshold
mapping, the sample matrix is tiled into (block_n, F) VMEM blocks; each grid
step broadcasts its block against the full (F, T) threshold grid on the VPU
and accumulates the (F, T) weighted-error tile in the output block, which
stays resident in VMEM across the sample-block grid (revisiting-output
pattern).  F is padded to the 128-lane boundary by the ops wrapper.

    err[f, t] = sum_i w_i * [ sign(x[i,f] - thr[f,t]) != y_i ]
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stump_kernel(x_ref, y_ref, w_ref, thr_ref, err_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        err_ref[...] = jnp.zeros_like(err_ref)

    x = x_ref[...].astype(jnp.float32)          # (bn, F)
    y = y_ref[...].astype(jnp.float32)          # (bn,)
    w = w_ref[...].astype(jnp.float32)          # (bn,)
    thr = thr_ref[...].astype(jnp.float32)      # (F, T)

    pred = jnp.where(x[:, :, None] > thr[None, :, :], 1.0, -1.0)  # (bn,F,T)
    miss = (pred != y[:, None, None]).astype(jnp.float32)
    err_ref[...] += jnp.einsum(
        "n,nft->ft", w, miss, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def stump_scan_kernel(x: jnp.ndarray, y: jnp.ndarray, w: jnp.ndarray,
                      thresholds: jnp.ndarray, *, block_n: int = 256,
                      interpret: bool = True) -> jnp.ndarray:
    """x: (N,F); y,w: (N,); thresholds: (F,T) -> (F,T) f32.
    N must be a multiple of block_n (ops wrapper pads with w=0 rows)."""
    N, F = x.shape
    T = thresholds.shape[1]
    assert N % block_n == 0, (N, block_n)
    grid = (N // block_n,)
    return pl.pallas_call(
        _stump_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, F), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((F, T), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((F, T), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((F, T), jnp.float32),
        interpret=interpret,
    )(x, y, w, thresholds)
