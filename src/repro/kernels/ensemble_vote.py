"""Pallas TPU kernel: fused weighted ensemble vote H(x) = sum_t a~_t h_t(x).

Fuses the (T-learner x N-sample) weighted reduction into one VMEM-resident
pass — the XLA fallback materializes the full scaled-margin tensor in HBM
(T x N x 4 bytes) before reducing; here each (block_t x block_n) tile is
reduced on the fly into the (block_n,) output accumulator.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _vote_kernel(m_ref, a_ref, out_ref):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    m = m_ref[...].astype(jnp.float32)      # (bt, bn)
    a = a_ref[...].astype(jnp.float32)      # (bt,)
    out_ref[...] += jnp.einsum("t,tn->n", a, m,
                               preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_t", "block_n", "interpret"))
def ensemble_vote_kernel(margins: jnp.ndarray, alphas: jnp.ndarray, *,
                         block_t: int = 128, block_n: int = 512,
                         interpret: bool = True) -> jnp.ndarray:
    """margins: (T,N); alphas: (T,) -> (N,) f32 ensemble margin.
    T, N must be multiples of the block sizes (ops wrapper pads with zeros;
    zero-alpha rows contribute nothing)."""
    T, N = margins.shape
    assert T % block_t == 0 and N % block_n == 0, (T, N, block_t, block_n)
    grid = (N // block_n, T // block_t)   # T innermost: accumulate per n-block
    return pl.pallas_call(
        _vote_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, block_n), lambda n, t: (t, n)),
            pl.BlockSpec((block_t,), lambda n, t: (t,)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda n, t: (n,)),
        out_shape=jax.ShapeDtypeStruct((N,), jnp.float32),
        interpret=interpret,
    )(margins, alphas)
