"""Pallas TPU kernel: fused weighted ensemble vote H(x) = sum_t a~_t h_t(x).

Fuses the (T-learner x N-sample) weighted reduction into one VMEM-resident
pass — the XLA fallback materializes the full scaled-margin tensor in HBM
(T x N x 4 bytes) before reducing; here each (block_t x block_n) tile is
reduced on the fly into the (block_n,) output accumulator.

Two batched variants serve the `repro.serve` hot path, where requests from
B tenants are packed into one padded (B, T, N) block:

* :func:`ensemble_vote_batched_kernel` — per-tenant weighted vote over
  precomputed margins (generic weak learners).
* :func:`stump_vote_batched_kernel`    — the stump fast path: the weak-
  learner prediction margin pol*sign(x[feat] - thr) and the weighted vote
  are fused in a single VMEM-resident pass, so the (T, N) margin tensor is
  never materialized in HBM.
* :func:`stump_vote_fp_batched_kernel` — the one-launch serving path: the
  stump margin, the weighted vote, *and* a per-column xor-fold feature
  fingerprint (two uint32 lanes, mixing constants shared with
  ``ref._fp_lanes``) in a single launch, so ``BatchEvaluator`` can key its
  result cache without re-walking any feature vector on the host.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import FP_ODD0, FP_ODD1, FP_SALT0, FP_SALT1


def _vote_kernel(m_ref, a_ref, out_ref):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    m = m_ref[...].astype(jnp.float32)      # (bt, bn)
    a = a_ref[...].astype(jnp.float32)      # (bt,)
    out_ref[...] += jnp.einsum("t,tn->n", a, m,
                               preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_t", "block_n", "interpret"))
def ensemble_vote_kernel(margins: jnp.ndarray, alphas: jnp.ndarray, *,
                         block_t: int = 128, block_n: int = 512,
                         interpret: bool = True) -> jnp.ndarray:
    """margins: (T,N); alphas: (T,) -> (N,) f32 ensemble margin.
    T, N must be multiples of the block sizes (ops wrapper pads with zeros;
    zero-alpha rows contribute nothing)."""
    T, N = margins.shape
    assert T % block_t == 0 and N % block_n == 0, (T, N, block_t, block_n)
    grid = (N // block_n, T // block_t)   # T innermost: accumulate per n-block
    return pl.pallas_call(
        _vote_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, block_n), lambda n, t: (t, n)),
            pl.BlockSpec((block_t,), lambda n, t: (t,)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda n, t: (n,)),
        out_shape=jax.ShapeDtypeStruct((N,), jnp.float32),
        interpret=interpret,
    )(margins, alphas)


# ---------------------------------------------------------------------------
# batched variants (serving hot path: one tenant per leading-axis slot)
# ---------------------------------------------------------------------------

def _batched_vote_kernel(m_ref, a_ref, out_ref):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    m = m_ref[0].astype(jnp.float32)        # (bt, bn)
    a = a_ref[0].astype(jnp.float32)        # (bt,)
    out_ref[0, :] += jnp.einsum("t,tn->n", a, m,
                                preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_t", "block_n", "interpret"))
def ensemble_vote_batched_kernel(margins: jnp.ndarray, alphas: jnp.ndarray, *,
                                 block_t: int = 128, block_n: int = 512,
                                 interpret: bool = True) -> jnp.ndarray:
    """margins: (B,T,N); alphas: (B,T) -> (B,N) f32 per-tenant ensemble
    margins.  T, N must be multiples of the block sizes (the ops wrapper
    pads with zero-alpha rows / dummy columns)."""
    B, T, N = margins.shape
    assert T % block_t == 0 and N % block_n == 0, (B, T, N, block_t, block_n)
    grid = (B, N // block_n, T // block_t)  # T innermost: accumulate per (b,n)
    return pl.pallas_call(
        _batched_vote_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, block_n), lambda b, n, t: (b, t, n)),
            pl.BlockSpec((1, block_t), lambda b, n, t: (b, t)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda b, n, t: (b, n)),
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.float32),
        interpret=interpret,
    )(margins, alphas)


def _stump_vote_kernel(x_ref, thr_ref, pol_ref, a_ref, out_ref):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[0].astype(jnp.float32)        # (bt, bn) gathered features
    thr = thr_ref[0].astype(jnp.float32)    # (bt,)
    pol = pol_ref[0].astype(jnp.float32)    # (bt,)
    a = a_ref[0].astype(jnp.float32)        # (bt,)
    # weak-learner margin and weighted vote fused in VMEM; the 1e-12
    # tiebreak matches fed_mesh._predict_stumps / models.weak.predict_stump
    m = pol[:, None] * jnp.sign(x - thr[:, None] + 1e-12)
    out_ref[0, :] += jnp.einsum("t,tn->n", a, m,
                                preferred_element_type=jnp.float32)


def _xor_fold(v: jnp.ndarray) -> jnp.ndarray:
    """XOR-reduce a (bt, bn) uint32 block over its row axis -> (bn,)."""
    return jax.lax.reduce(v, jnp.uint32(0), jax.lax.bitwise_xor, (0,))


def _stump_vote_fp_kernel(x_ref, thr_ref, pol_ref, a_ref,
                          out_ref, f0_ref, f1_ref, *, block_t: int):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)
        f0_ref[...] = jnp.zeros_like(f0_ref)
        f1_ref[...] = jnp.zeros_like(f1_ref)

    x = x_ref[0].astype(jnp.float32)        # (bt, bn) gathered features
    thr = thr_ref[0].astype(jnp.float32)    # (bt,)
    pol = pol_ref[0].astype(jnp.float32)    # (bt,)
    a = a_ref[0].astype(jnp.float32)        # (bt,)
    m = pol[:, None] * jnp.sign(x - thr[:, None] + 1e-12)
    out_ref[0, :] += jnp.einsum("t,tn->n", a, m,
                                preferred_element_type=jnp.float32)

    # xor-fold fingerprint: same mixing as ref._fp_lanes, with the row
    # position offset by this block's place in the t grid.  alpha-gating
    # makes zero-alpha padding rows the XOR identity, so the fingerprint
    # is invariant under the batch's T padding; XOR associativity makes
    # it invariant under the block layout.
    bits = jax.lax.bitcast_convert_type(x, jnp.uint32)
    tt = (jnp.uint32(t * block_t)
          + jax.lax.broadcasted_iota(jnp.uint32, x.shape, 0))
    live = (a != 0.0)[:, None]
    zero = jnp.zeros_like(bits)
    f0_ref[0, :] ^= _xor_fold(jnp.where(
        live, (bits ^ jnp.uint32(FP_SALT0)) * (2 * tt + FP_ODD0), zero))
    f1_ref[0, :] ^= _xor_fold(jnp.where(
        live, (bits ^ jnp.uint32(FP_SALT1)) * (2 * tt + FP_ODD1), zero))


@functools.partial(jax.jit, static_argnames=("block_t", "block_n", "interpret"))
def stump_vote_fp_batched_kernel(xsel: jnp.ndarray, thr: jnp.ndarray,
                                 pol: jnp.ndarray, alphas: jnp.ndarray, *,
                                 block_t: int = 128, block_n: int = 512,
                                 interpret: bool = True):
    """Fused stump prediction + weighted vote + feature fingerprint.

    Same contract as :func:`stump_vote_batched_kernel` plus two uint32
    fingerprint outputs: ``(margins (B,N) f32, fp0 (B,N) u32,
    fp1 (B,N) u32)``.  Zero-alpha padding rows contribute nothing to the
    vote *or* the fingerprint, so both are stable across batch packing.
    """
    B, T, N = xsel.shape
    assert T % block_t == 0 and N % block_n == 0, (B, T, N, block_t, block_n)
    grid = (B, N // block_n, T // block_t)
    kern = functools.partial(_stump_vote_fp_kernel, block_t=block_t)
    vec = pl.BlockSpec((1, block_t), lambda b, n, t: (b, t))
    col = pl.BlockSpec((1, block_n), lambda b, n, t: (b, n))
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, block_n), lambda b, n, t: (b, t, n)),
            vec, vec, vec,
        ],
        out_specs=[col, col, col],
        out_shape=[
            jax.ShapeDtypeStruct((B, N), jnp.float32),
            jax.ShapeDtypeStruct((B, N), jnp.uint32),
            jax.ShapeDtypeStruct((B, N), jnp.uint32),
        ],
        interpret=interpret,
    )(xsel, thr, pol, alphas)


@functools.partial(jax.jit, static_argnames=("block_t", "block_n", "interpret"))
def stump_vote_batched_kernel(xsel: jnp.ndarray, thr: jnp.ndarray,
                              pol: jnp.ndarray, alphas: jnp.ndarray, *,
                              block_t: int = 128, block_n: int = 512,
                              interpret: bool = True) -> jnp.ndarray:
    """Fused stump prediction + weighted vote.

    xsel: (B,T,N) pre-gathered features xsel[b,t,n] = x_b[n, feat_{b,t}];
    thr, pol, alphas: (B,T) -> (B,N) f32 ensemble margins.  Zero-alpha
    padding rows contribute nothing regardless of thr/pol."""
    B, T, N = xsel.shape
    assert T % block_t == 0 and N % block_n == 0, (B, T, N, block_t, block_n)
    grid = (B, N // block_n, T // block_t)
    return pl.pallas_call(
        _stump_vote_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, block_n), lambda b, n, t: (b, t, n)),
            pl.BlockSpec((1, block_t), lambda b, n, t: (b, t)),
            pl.BlockSpec((1, block_t), lambda b, n, t: (b, t)),
            pl.BlockSpec((1, block_t), lambda b, n, t: (b, t)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda b, n, t: (b, n)),
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.float32),
        interpret=interpret,
    )(xsel, thr, pol, alphas)
