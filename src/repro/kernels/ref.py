"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` function defines the semantics the kernel must match
(asserted allclose in tests over shape/dtype sweeps, with the kernel run in
interpret mode on CPU).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def stump_scan_ref(x: jnp.ndarray, y: jnp.ndarray, w: jnp.ndarray,
                   thresholds: jnp.ndarray) -> jnp.ndarray:
    """Weighted error of the polarity-(+1) stump for every (feature,
    threshold) pair.

    x: (N,F); y: (N,) in {-1,+1}; w: (N,); thresholds: (F,T) -> (F,T) f32.

    err[f,t] = sum_i w_i * [ sign(x[i,f] - thr[f,t]) != y_i ]
    (sign(0) counts as -1: strict `>` decides the +1 side.)
    """
    pred = jnp.where(x[:, :, None] > thresholds[None, :, :], 1.0, -1.0)
    miss = (pred != y[:, None, None]).astype(jnp.float32)
    return jnp.einsum("n,nft->ft", w.astype(jnp.float32), miss)


def stump_scan_batched_ref(x: jnp.ndarray, y: jnp.ndarray, w: jnp.ndarray,
                           thresholds: jnp.ndarray) -> jnp.ndarray:
    """Per-client weighted stump errors for a stacked fleet batch.

    x: (B,N,F); y, w: (B,N); thresholds: (B,F,T) -> (B,F,T) f32 — exactly
    :func:`stump_scan_ref` per batch slot.  Rows padded with w = 0
    contribute nothing, so ragged client shards stack safely.
    """
    return jax.vmap(stump_scan_ref)(x, y, w, thresholds)


def ensemble_vote_ref(margins: jnp.ndarray, alphas: jnp.ndarray) -> jnp.ndarray:
    """Weighted ensemble margin: H(x) = sum_t alpha_t h_t(x).

    margins: (T, N) per-learner predictions in [-1, 1]; alphas: (T,)
    (already staleness-compensated) -> (N,) f32 ensemble margin.
    """
    return jnp.einsum("t,tn->n", alphas.astype(jnp.float32),
                      margins.astype(jnp.float32))


def ensemble_vote_batched_ref(margins: jnp.ndarray, alphas: jnp.ndarray
                              ) -> jnp.ndarray:
    """Per-tenant weighted ensemble margins (serving batch path).

    margins: (B, T, N) per-learner predictions for B packed tenants;
    alphas: (B, T) -> (B, N) f32 ensemble margins.
    """
    return jnp.einsum("bt,btn->bn", alphas.astype(jnp.float32),
                      margins.astype(jnp.float32))


def stump_vote_batched_ref(xsel: jnp.ndarray, thr: jnp.ndarray,
                           pol: jnp.ndarray, alphas: jnp.ndarray
                           ) -> jnp.ndarray:
    """Fused stump prediction + weighted vote (serving stump fast path).

    xsel: (B, T, N) gathered features xsel[b,t,n] = x_b[n, feat_{b,t}];
    thr, pol, alphas: (B, T) -> (B, N) f32 ensemble margins.  The 1e-12
    sign tiebreak matches the stump predictors used at training time.
    """
    m = (pol[:, :, None].astype(jnp.float32)
         * jnp.sign(xsel.astype(jnp.float32)
                    - thr[:, :, None].astype(jnp.float32) + 1e-12))
    return jnp.einsum("bt,btn->bn", alphas.astype(jnp.float32), m)


# Feature-fingerprint mixing constants, shared verbatim with the fused
# Pallas kernel (kernels/ensemble_vote.py) so oracle and kernel fold the
# same bits: two independent 32-bit lanes give a 64-bit fingerprint.  The
# multiplier 2*t + ODD is always odd (invertible mod 2^32), making the
# fold position-sensitive; rows are gated on alpha != 0 so zero-alpha
# padding rows contribute the XOR identity and the fingerprint is
# invariant under the serving batch's T padding.
FP_SALT0 = 0x9E3779B9
FP_SALT1 = 0x85EBCA6B
FP_ODD0 = 0x0001_0001
FP_ODD1 = 0x00C2_B2AF


def _fp_lanes(xsel: jnp.ndarray, alphas: jnp.ndarray):
    """The two uint32 fingerprint lanes of each (batch, column) pair.

    xsel: (B, T, N) float features; alphas: (B, T).  Lane k folds
    ``XOR_t [(bits(x[t]) ^ SALT_k) * (2 t + ODD_k)]`` over the rows with
    ``alpha_t != 0``.  Because alpha-zero rows contribute nothing to the
    weighted vote either, two columns sharing a fingerprint under the same
    (tenant, version) alphas share the ensemble margin too.
    """
    bits = jax.lax.bitcast_convert_type(xsel.astype(jnp.float32),
                                        jnp.uint32)              # (B, T, N)
    T = xsel.shape[1]
    tt = jnp.arange(T, dtype=jnp.uint32)[None, :, None]
    live = (alphas.astype(jnp.float32) != 0.0)[:, :, None]
    zero = jnp.zeros_like(bits)
    c0 = jnp.where(live,
                   (bits ^ jnp.uint32(FP_SALT0)) * (2 * tt + FP_ODD0), zero)
    c1 = jnp.where(live,
                   (bits ^ jnp.uint32(FP_SALT1)) * (2 * tt + FP_ODD1), zero)
    f0 = jax.lax.reduce(c0, jnp.uint32(0), jax.lax.bitwise_xor, (1,))
    f1 = jax.lax.reduce(c1, jnp.uint32(0), jax.lax.bitwise_xor, (1,))
    return f0, f1


def stump_vote_fp_batched_ref(xsel: jnp.ndarray, thr: jnp.ndarray,
                              pol: jnp.ndarray, alphas: jnp.ndarray):
    """Fused stump vote + per-column feature fingerprint (serving one-launch
    path).

    Same margin semantics as :func:`stump_vote_batched_ref`, plus two
    uint32 fingerprint lanes per column — ``(margins (B,N) f32,
    fp0 (B,N) u32, fp1 (B,N) u32)``.  The fingerprint lanes are *exact*
    integers: every backend must reproduce them bit-for-bit (XOR folding
    is order-independent, so block layout cannot perturb them).
    """
    margins = stump_vote_batched_ref(xsel, thr, pol, alphas)
    f0, f1 = _fp_lanes(xsel, alphas)
    return margins, f0, f1


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, causal: bool = True) -> jnp.ndarray:
    """Plain softmax attention.  q,k,v: (B,H,T,hd) -> (B,H,T,hd)."""
    Tq, Tk = q.shape[2], k.shape[2]
    scale = 1.0 / math.sqrt(q.shape[-1])
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((Tq, Tk), bool), k=Tk - Tq)
        logits = jnp.where(mask[None, None], logits, -1e30)
    wts = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", wts, v.astype(jnp.float32))
    return out.astype(q.dtype)


def dist_update_ref(alpha, D, y, h):
    """AdaBoost distribution update (paper eq. 4): returns normalized D'.

    D'_i = D_i exp(-alpha y_i h_i) / Z,  Z = sum_i D_i exp(-alpha y_i h_i).
    """
    import jax.numpy as _jnp
    w = D.astype(_jnp.float32) * _jnp.exp(
        -_jnp.asarray(alpha, _jnp.float32) * y.astype(_jnp.float32)
        * h.astype(_jnp.float32))
    Z = _jnp.sum(w)
    return w / (Z + 1e-30), Z
