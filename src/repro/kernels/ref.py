"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` function defines the semantics the kernel must match
(asserted allclose in tests over shape/dtype sweeps, with the kernel run in
interpret mode on CPU).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def stump_scan_ref(x: jnp.ndarray, y: jnp.ndarray, w: jnp.ndarray,
                   thresholds: jnp.ndarray) -> jnp.ndarray:
    """Weighted error of the polarity-(+1) stump for every (feature,
    threshold) pair.

    x: (N,F); y: (N,) in {-1,+1}; w: (N,); thresholds: (F,T) -> (F,T) f32.

    err[f,t] = sum_i w_i * [ sign(x[i,f] - thr[f,t]) != y_i ]
    (sign(0) counts as -1: strict `>` decides the +1 side.)
    """
    pred = jnp.where(x[:, :, None] > thresholds[None, :, :], 1.0, -1.0)
    miss = (pred != y[:, None, None]).astype(jnp.float32)
    return jnp.einsum("n,nft->ft", w.astype(jnp.float32), miss)


def stump_scan_batched_ref(x: jnp.ndarray, y: jnp.ndarray, w: jnp.ndarray,
                           thresholds: jnp.ndarray) -> jnp.ndarray:
    """Per-client weighted stump errors for a stacked fleet batch.

    x: (B,N,F); y, w: (B,N); thresholds: (B,F,T) -> (B,F,T) f32 — exactly
    :func:`stump_scan_ref` per batch slot.  Rows padded with w = 0
    contribute nothing, so ragged client shards stack safely.
    """
    return jax.vmap(stump_scan_ref)(x, y, w, thresholds)


def ensemble_vote_ref(margins: jnp.ndarray, alphas: jnp.ndarray) -> jnp.ndarray:
    """Weighted ensemble margin: H(x) = sum_t alpha_t h_t(x).

    margins: (T, N) per-learner predictions in [-1, 1]; alphas: (T,)
    (already staleness-compensated) -> (N,) f32 ensemble margin.
    """
    return jnp.einsum("t,tn->n", alphas.astype(jnp.float32),
                      margins.astype(jnp.float32))


def ensemble_vote_batched_ref(margins: jnp.ndarray, alphas: jnp.ndarray
                              ) -> jnp.ndarray:
    """Per-tenant weighted ensemble margins (serving batch path).

    margins: (B, T, N) per-learner predictions for B packed tenants;
    alphas: (B, T) -> (B, N) f32 ensemble margins.
    """
    return jnp.einsum("bt,btn->bn", alphas.astype(jnp.float32),
                      margins.astype(jnp.float32))


def stump_vote_batched_ref(xsel: jnp.ndarray, thr: jnp.ndarray,
                           pol: jnp.ndarray, alphas: jnp.ndarray
                           ) -> jnp.ndarray:
    """Fused stump prediction + weighted vote (serving stump fast path).

    xsel: (B, T, N) gathered features xsel[b,t,n] = x_b[n, feat_{b,t}];
    thr, pol, alphas: (B, T) -> (B, N) f32 ensemble margins.  The 1e-12
    sign tiebreak matches the stump predictors used at training time.
    """
    m = (pol[:, :, None].astype(jnp.float32)
         * jnp.sign(xsel.astype(jnp.float32)
                    - thr[:, :, None].astype(jnp.float32) + 1e-12))
    return jnp.einsum("bt,btn->bn", alphas.astype(jnp.float32), m)


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, causal: bool = True) -> jnp.ndarray:
    """Plain softmax attention.  q,k,v: (B,H,T,hd) -> (B,H,T,hd)."""
    Tq, Tk = q.shape[2], k.shape[2]
    scale = 1.0 / math.sqrt(q.shape[-1])
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((Tq, Tk), bool), k=Tk - Tq)
        logits = jnp.where(mask[None, None], logits, -1e30)
    wts = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", wts, v.astype(jnp.float32))
    return out.astype(q.dtype)


def dist_update_ref(alpha, D, y, h):
    """AdaBoost distribution update (paper eq. 4): returns normalized D'.

    D'_i = D_i exp(-alpha y_i h_i) / Z,  Z = sum_i D_i exp(-alpha y_i h_i).
    """
    import jax.numpy as _jnp
    w = D.astype(_jnp.float32) * _jnp.exp(
        -_jnp.asarray(alpha, _jnp.float32) * y.astype(_jnp.float32)
        * h.astype(_jnp.float32))
    Z = _jnp.sum(w)
    return w / (Z + 1e-30), Z
