"""Pallas TPU kernel: fused AdaBoost sample-distribution update
(paper eq. 4) — the other per-round hot-spot of the boosting loop.

    w_i = D_i * exp(-alpha * y_i * h_i)        (elementwise)
    Z   = sum_i w_i                            (reduction)
    D'_i = w_i / Z                             (normalize)

The XLA fallback materializes w to HBM, reduces it, then re-reads it for
the divide — three passes over N.  The kernel computes w and the running Z
in one VMEM pass (revisiting a (1,1) scalar accumulator block); the ops
wrapper fuses the final scale.  On multi-million-sample clients this is
the difference between one and three HBM sweeps per boosting round.

VMEM tiling: (block_n,) stripes of D/y/h; scalar accumulator revisited
across the grid (TPU grid is sequential on-core, so the accumulation is
race-free by construction).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dist_update_kernel(alpha_ref, d_ref, y_ref, h_ref, w_ref, z_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        z_ref[...] = jnp.zeros_like(z_ref)

    alpha = alpha_ref[0]
    d = d_ref[...].astype(jnp.float32)
    y = y_ref[...].astype(jnp.float32)
    h = h_ref[...].astype(jnp.float32)
    w = d * jnp.exp(-alpha * y * h)
    w_ref[...] = w
    z_ref[...] += jnp.sum(w)[None]


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def dist_update_kernel(alpha: jnp.ndarray, D: jnp.ndarray, y: jnp.ndarray,
                       h: jnp.ndarray, *, block_n: int = 1024,
                       interpret: bool = True):
    """alpha: () f32; D,y,h: (N,) -> (w (N,) f32, Z (1,) f32).
    N must be a multiple of block_n (ops wrapper pads with D=0 rows)."""
    N = D.shape[0]
    assert N % block_n == 0, (N, block_n)
    grid = (N // block_n,)
    return pl.pallas_call(
        _dist_update_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ],
        interpret=interpret,
    )(alpha.reshape(1), D, y, h)
