"""Unified kernel-backend dispatch with shape-bucketed autotuning.

Every public kernel entry point in :mod:`repro.kernels.ops` routes through
this module.  Three execution substrates implement the same numerical
contract (asserted against each other in ``tests/test_backend_parity.py``):

* ``interpret`` — the Pallas kernels under the Pallas interpreter.  Runs on
  any JAX backend; the CPU-container default.
* ``mosaic``    — the same Pallas kernels compiled by Mosaic.  TPU only.
* ``xla``       — the pure-jnp oracles from :mod:`repro.kernels.ref`,
  jit-compiled by XLA.  Always available; the fallback of last resort and
  frequently the fastest substrate on CPU.

Backend choice is re-resolved on *every* call (nothing is captured at
construction time — a policy/env change or a TPU hot-attach takes effect on
the next kernel launch), in priority order::

    explicit ``backend=`` argument (or the deprecated ``interpret=`` shim)
    > ``KernelPolicy(backend=...)`` forced policy
    > the ``REPRO_KERNEL_BACKEND`` environment variable
    > the policy's calibration table (per (kernel, shape-bucket) winner)
    > platform default ("mosaic" on TPU, "interpret" elsewhere)

An unavailable candidate (e.g. ``mosaic`` off-TPU) falls through to the
next priority with a one-shot RuntimeWarning, so a policy calibrated on one
substrate degrades gracefully on another.

Shapes are *bucketed* by rounding each dimension up to the block boundary
the padded Pallas call would use — under the kernel's **reference layout**
(``DEFAULT_LAYOUTS``), never the candidate layout under test — so every
raw shape that lowers to the same padded reference kernel shares one
calibration measurement and one entry in the per-(kernel, bucket, backend)
dispatch cache, and every candidate layout of one call shares a single
table entry.

Calibration is a **layout autotune**, not just a backend choice:
``KernelPolicy.calibrate_call`` times each available backend over a small
grid of block layouts (``LAYOUT_GRIDS`` — ``(block_t, block_n)`` for the
vote kernels, ``block_n`` for stump_scan/dist_update, ``(block_q,
block_k)`` for flash attention, following the xformers Triton config-sweep
pattern) and records the ``(winner_backend, winner_layout)`` pair per
(kernel, bucket).  ``dispatch()`` then injects the winning layout kwargs
on every resolved call whose backend matches the winner — explicit caller
layout kwargs still win.  ``save``/``load`` persist the table to JSON
(schema v2; v1 backend-only tables load transparently with empty layouts;
default ``artifacts/backend_calibration.json``) so serving restarts skip
recalibration — see ``benchmarks/backend_matrix.py`` for the one-shot
sweep pass.
"""
from __future__ import annotations

import functools
import json
import os
import statistics
import time
import warnings
from pathlib import Path
from typing import (Callable, Dict, List, NamedTuple, Optional, Sequence,
                    Tuple)

import jax
import jax.numpy as jnp

from repro import obs
from repro.kernels import ref
from repro.kernels.dist_update import dist_update_kernel
from repro.kernels.ensemble_vote import (
    ensemble_vote_batched_kernel, ensemble_vote_kernel,
    stump_vote_batched_kernel, stump_vote_fp_batched_kernel)
from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.stump_scan import stump_scan_kernel

ENV_VAR = "REPRO_KERNEL_BACKEND"
DEFAULT_CALIBRATION_PATH = "artifacts/backend_calibration.json"
CALIBRATION_SCHEMA_VERSION = 2

# (measured_on, running_on) pairs already warned about — the cross-
# platform calibration warning fires once per process per pair
_PLATFORM_WARNED: set = set()

Bucket = Tuple[int, ...]
Layout = Dict[str, int]                 # block-shape kwargs of one launch
LayoutKey = Tuple[Tuple[str, int], ...]  # canonical (sorted items) form

# The block-shape kwargs the autotuner owns.  Any of these passed as None
# by an ops wrapper means "let the calibration table (or the reference
# layout) decide"; an explicit int always wins.
LAYOUT_KWARGS = ("block_t", "block_n", "block_q", "block_k")

# Reference layouts: the pre-autotune hardcoded defaults.  Buckets are
# always computed against these (layout-canonical bucketing), and they are
# the fallback layout when the table has no tuned entry for the resolved
# backend.
DEFAULT_LAYOUTS: Dict[str, Layout] = {
    "stump_scan": {"block_n": 256},
    "stump_scan_batched": {"block_n": 256},
    "ensemble_vote": {"block_t": 128, "block_n": 512},
    "ensemble_vote_batched": {"block_t": 128, "block_n": 512},
    "stump_vote_batched": {"block_t": 128, "block_n": 512},
    "stump_vote_fp_batched": {"block_t": 128, "block_n": 512},
    "flash_attention": {"block_q": 128, "block_k": 128},
    "dist_update": {"block_n": 1024},
}

# The sweep grid per kernel (each entry is one complete candidate layout;
# the reference layout is always a member).  Kept small on purpose — the
# xformers Triton sweeps that inspired this stay in the single digits per
# kernel too; a candidate that clamps to the same effective blocks as
# another (small problem sizes) just measures the same launch twice.
_VOTE_GRID = [
    {"block_t": 64, "block_n": 256},
    {"block_t": 128, "block_n": 512},       # reference
    {"block_t": 128, "block_n": 1024},
    {"block_t": 256, "block_n": 2048},
]
LAYOUT_GRIDS: Dict[str, List[Layout]] = {
    "stump_scan": [{"block_n": 128}, {"block_n": 256}, {"block_n": 512},
                   {"block_n": 1024}],
    "stump_scan_batched": [{"block_n": 128}, {"block_n": 256},
                           {"block_n": 512}, {"block_n": 1024}],
    "ensemble_vote": _VOTE_GRID,
    "ensemble_vote_batched": _VOTE_GRID,
    "stump_vote_batched": _VOTE_GRID,
    "stump_vote_fp_batched": _VOTE_GRID,
    "flash_attention": [{"block_q": 64, "block_k": 64},
                        {"block_q": 128, "block_k": 128},   # reference
                        {"block_q": 128, "block_k": 256},
                        {"block_q": 256, "block_k": 256}],
    "dist_update": [{"block_n": 512}, {"block_n": 1024}, {"block_n": 2048},
                    {"block_n": 4096}],
}


def layout_key(layout) -> LayoutKey:
    """Canonical hashable form of a layout (dict or item tuple -> sorted
    ``((kwarg, int), ...)``)."""
    if not layout:
        return ()
    items = layout.items() if isinstance(layout, dict) else layout
    return tuple(sorted((str(k), int(v)) for k, v in items))


def layout_label(layout) -> str:
    """Render a layout for logs/metrics ("block_n=512,block_t=128")."""
    items = layout if isinstance(layout, tuple) else layout_key(layout)
    return ",".join(f"{k}={v}" for k, v in items) or "-"


class CalEntry(NamedTuple):
    """One calibration-table value: the winning backend and its layout."""
    backend: str
    layout: LayoutKey = ()


def _entry(value) -> "CalEntry":
    """Normalize a calibration-table value to :class:`CalEntry`.

    Accepts a bare backend name (the v1 / pre-layout form), a CalEntry, a
    ``(backend, layout)`` pair, or a ``{"backend": ..., "layout": ...}``
    dict — so v1 tables, hand-written test tables, and serialized v2
    entries all coexist."""
    if isinstance(value, CalEntry):
        return CalEntry(canonical(value.backend), layout_key(value.layout))
    if isinstance(value, str):
        return CalEntry(canonical(value))
    if isinstance(value, dict):
        return CalEntry(canonical(value["backend"]),
                        layout_key(value.get("layout")))
    backend, layout = value
    return CalEntry(canonical(backend), layout_key(layout))


# ---------------------------------------------------------------------------
# shared shape helpers (the single home of the padding boilerplate that used
# to be copy-pasted across every ops.py wrapper)
# ---------------------------------------------------------------------------

def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pad_to(x: jnp.ndarray, axis: int, mult: int, value=0.0) -> jnp.ndarray:
    """Pad ``axis`` up to the next multiple of ``mult`` with ``value``."""
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def ceil_to(n: int, mult: int) -> int:
    return -(-n // mult) * mult


def next_pow2(n: int) -> int:
    return 1 << (max(int(n), 1) - 1).bit_length()


def vote_blocks(T: int, N: int, block_t: int, block_n: int) -> Tuple[int, int]:
    """Effective (block_t, block_n) for the vote kernels: shrink to the next
    power of two covering the problem so tiny ensembles don't pad to 128."""
    bt = min(block_t, max(8, next_pow2(T)))
    bn = min(block_n, max(128, next_pow2(N)))
    return bt, bn


def _largest_divisor_leq(n: int, cap: int) -> int:
    """Largest divisor of ``n`` that is <= ``cap`` (>= 1)."""
    for d in range(min(int(cap), int(n)), 0, -1):
        if n % d == 0:
            return d
    return 1


def _flash_blocks(T: int, block_q: int, block_k: int) -> Tuple[int, int]:
    # largest divisor of T at or under the requested block, so ragged
    # sequence lengths still tile (T=192 with block_q=128 runs 96-tiled,
    # not as one untiled T-slab)
    return (_largest_divisor_leq(T, block_q), _largest_divisor_leq(T, block_k))


# ---------------------------------------------------------------------------
# Pallas substrate: pad to hardware-aligned blocks, launch, slice back
# ---------------------------------------------------------------------------

def _pallas_stump_scan(x, y, w, thresholds, *, block_n=256, interpret=True):
    # pad N with zero-weight rows (no contribution) and F/T to the 8-sublane
    # boundary (inf thresholds never win the argmin)
    N, F = x.shape
    T = thresholds.shape[1]
    xp = pad_to(x, 0, block_n)
    yp = pad_to(y, 0, block_n, value=1.0)
    wp = pad_to(w, 0, block_n, value=0.0)
    xp = pad_to(xp, 1, 8)
    thr = pad_to(pad_to(thresholds, 0, 8, value=jnp.inf), 1, 8,
                 value=jnp.inf)
    err = stump_scan_kernel(xp, yp, wp, thr, block_n=block_n,
                            interpret=interpret)
    return err[:F, :T]


def _pallas_stump_scan_batched(x, y, w, thresholds, *, block_n=256,
                               interpret=True):
    # vmap lifts the batch dim onto the launch grid; per-slot padding is
    # identical to _pallas_stump_scan.  block_n shrinks to the next power
    # of two covering N so fleet batches of tiny shards don't pad 64x.
    N = x.shape[1]
    bn = min(block_n, max(8, next_pow2(N)))
    fn = functools.partial(_pallas_stump_scan, block_n=bn,
                           interpret=interpret)
    return jax.vmap(fn)(x, y, w, thresholds)


def _pallas_ensemble_vote(margins, alphas, *, block_t=128, block_n=512,
                          interpret=True):
    # pad T with zero-alpha rows and N with dummy columns (sliced off)
    T, N = margins.shape
    bt, bn = vote_blocks(T, N, block_t, block_n)
    mp = pad_to(pad_to(margins, 0, bt), 1, bn)
    ap = pad_to(alphas, 0, bt, value=0.0)
    out = ensemble_vote_kernel(mp, ap, block_t=bt, block_n=bn,
                               interpret=interpret)
    return out[:N]


def _pallas_ensemble_vote_batched(margins, alphas, *, block_t=128,
                                  block_n=512, interpret=True):
    B, T, N = margins.shape
    bt, bn = vote_blocks(T, N, block_t, block_n)
    mp = pad_to(pad_to(margins, 1, bt), 2, bn)
    ap = pad_to(alphas, 1, bt, value=0.0)
    out = ensemble_vote_batched_kernel(mp, ap, block_t=bt, block_n=bn,
                                       interpret=interpret)
    return out[:, :N]


def _pallas_stump_vote_batched(xsel, thr, pol, alphas, *, block_t=128,
                               block_n=512, interpret=True):
    # zero-alpha padding rows nullify whatever thr/pol padding holds
    B, T, N = xsel.shape
    bt, bn = vote_blocks(T, N, block_t, block_n)
    xp = pad_to(pad_to(xsel, 1, bt), 2, bn)
    tp = pad_to(thr, 1, bt, value=0.0)
    pp = pad_to(pol, 1, bt, value=1.0)
    ap = pad_to(alphas, 1, bt, value=0.0)
    out = stump_vote_batched_kernel(xp, tp, pp, ap, block_t=bt, block_n=bn,
                                    interpret=interpret)
    return out[:, :N]


def _pallas_stump_vote_fp_batched(xsel, thr, pol, alphas, *, block_t=128,
                                  block_n=512, interpret=True):
    # same padding contract as stump_vote_batched; the alpha-gated xor
    # fold makes the fingerprint outputs padding-invariant too
    B, T, N = xsel.shape
    bt, bn = vote_blocks(T, N, block_t, block_n)
    xp = pad_to(pad_to(xsel, 1, bt), 2, bn)
    tp = pad_to(thr, 1, bt, value=0.0)
    pp = pad_to(pol, 1, bt, value=1.0)
    ap = pad_to(alphas, 1, bt, value=0.0)
    out, f0, f1 = stump_vote_fp_batched_kernel(
        xp, tp, pp, ap, block_t=bt, block_n=bn, interpret=interpret)
    return out[:, :N], f0[:, :N], f1[:, :N]


def _pallas_flash_attention(q, k, v, *, causal=True, block_q=128,
                            block_k=128, interpret=True):
    B, H, T, d = q.shape
    bq, bk = _flash_blocks(T, block_q, block_k)
    qf = q.reshape(B * H, T, d)
    kf = k.reshape(B * H, T, d)
    vf = v.reshape(B * H, T, d)
    dp = (-d) % 128
    if dp:
        # zero-pad head_dim: extra lanes contribute 0 to q.k and to output
        qf = pad_to(qf, 2, 128)
        kf = pad_to(kf, 2, 128)
        vf = pad_to(vf, 2, 128)
        # the kernel scales by 1/sqrt(d_padded); pre-scale q so the
        # effective scale reflects the true head_dim
        qf = qf * (((d + dp) ** 0.5) / (d ** 0.5))
    out = flash_attention_kernel(
        qf, kf, vf, causal=causal, block_q=bq, block_k=bk,
        interpret=interpret)
    out = out[..., :d]
    return out.reshape(B, H, T, d)


def _pallas_dist_update(alpha, D, y, h, *, block_n=1024, interpret=True):
    # pad N with zero-mass rows (no contribution to Z)
    N = D.shape[0]
    bn = min(block_n, max(256, next_pow2(N)))
    Dp = pad_to(D, 0, bn, value=0.0)
    yp = pad_to(y, 0, bn, value=1.0)
    hp = pad_to(h, 0, bn, value=0.0)
    w, Z = dist_update_kernel(jnp.asarray(alpha, jnp.float32), Dp, yp, hp,
                              block_n=bn, interpret=interpret)
    return (w / (Z[0] + 1e-30))[:N], Z[0]


_PALLAS_IMPLS: Dict[str, Callable] = {
    "stump_scan": _pallas_stump_scan,
    "stump_scan_batched": _pallas_stump_scan_batched,
    "ensemble_vote": _pallas_ensemble_vote,
    "ensemble_vote_batched": _pallas_ensemble_vote_batched,
    "stump_vote_batched": _pallas_stump_vote_batched,
    "stump_vote_fp_batched": _pallas_stump_vote_fp_batched,
    "flash_attention": _pallas_flash_attention,
    "dist_update": _pallas_dist_update,
}


# ---------------------------------------------------------------------------
# XLA substrate: the ref.py oracles on the raw (unpadded) inputs,
# jit-compiled so the fallback path is a real compiled alternative (not an
# eager op-by-op walk) — what calibration then measures and persists
# ---------------------------------------------------------------------------

_jit_stump_scan_ref = jax.jit(ref.stump_scan_ref)
_jit_stump_scan_batched_ref = jax.jit(ref.stump_scan_batched_ref)
_jit_ensemble_vote_ref = jax.jit(ref.ensemble_vote_ref)
_jit_ensemble_vote_batched_ref = jax.jit(ref.ensemble_vote_batched_ref)
_jit_stump_vote_batched_ref = jax.jit(ref.stump_vote_batched_ref)
_jit_stump_vote_fp_batched_ref = jax.jit(ref.stump_vote_fp_batched_ref)
_jit_flash_attention_ref = jax.jit(ref.flash_attention_ref,
                                   static_argnames=("causal",))
_jit_dist_update_ref = jax.jit(ref.dist_update_ref)

_XLA_IMPLS: Dict[str, Callable] = {
    "stump_scan":
        lambda x, y, w, thr, **_: _jit_stump_scan_ref(x, y, w, thr),
    "stump_scan_batched":
        lambda x, y, w, thr, **_: _jit_stump_scan_batched_ref(x, y, w, thr),
    "ensemble_vote":
        lambda m, a, **_: _jit_ensemble_vote_ref(m, a),
    "ensemble_vote_batched":
        lambda m, a, **_: _jit_ensemble_vote_batched_ref(m, a),
    "stump_vote_batched":
        lambda x, t, p, a, **_: _jit_stump_vote_batched_ref(x, t, p, a),
    "stump_vote_fp_batched":
        lambda x, t, p, a, **_: _jit_stump_vote_fp_batched_ref(x, t, p, a),
    "flash_attention":
        lambda q, k, v, *, causal=True, **_:
            _jit_flash_attention_ref(q, k, v, causal=causal),
    "dist_update":
        lambda alpha, D, y, h, **_: _jit_dist_update_ref(alpha, D, y, h),
}

KERNELS: Tuple[str, ...] = tuple(_PALLAS_IMPLS)


# ---------------------------------------------------------------------------
# shape buckets: round every call up to the padded shape it lowers to under
# the kernel's *reference* layout (DEFAULT_LAYOUTS) — never the candidate
# layout under test — so calls sharing one compiled reference kernel share
# one calibration/dispatch entry and every candidate layout of one call
# maps to the same table entry (layout-canonical bucketing)
# ---------------------------------------------------------------------------

def _bucket_stump_scan(x, y, w, thresholds, **_):
    N, F = x.shape
    T = thresholds.shape[1]
    return (ceil_to(N, 256), ceil_to(F, 8), ceil_to(T, 8))


def _bucket_stump_scan_batched(x, y, w, thresholds, **_):
    B, N, F = x.shape
    T = thresholds.shape[2]
    bn = min(256, max(8, next_pow2(N)))
    return (next_pow2(B), ceil_to(N, bn), ceil_to(F, 8), ceil_to(T, 8))


def _bucket_ensemble_vote(margins, alphas, **_):
    T, N = margins.shape
    bt, bn = vote_blocks(T, N, 128, 512)
    return (ceil_to(T, bt), ceil_to(N, bn))


def _bucket_vote_batched(margins, alphas, **_):
    B, T, N = margins.shape
    bt, bn = vote_blocks(T, N, 128, 512)
    return (next_pow2(B), ceil_to(T, bt), ceil_to(N, bn))


def _bucket_stump_vote_batched(xsel, thr, pol, alphas, **_):
    return _bucket_vote_batched(xsel, alphas)


def _bucket_flash_attention(q, k, v, **_):
    B, H, T, d = q.shape
    bq, bk = _flash_blocks(T, 128, 128)
    return (next_pow2(B * H), ceil_to(T, bq), ceil_to(d, 128))


def _bucket_dist_update(alpha, D, y, h, **_):
    N = D.shape[0]
    bn = min(1024, max(256, next_pow2(N)))
    return (ceil_to(N, bn),)


_BUCKETERS: Dict[str, Callable[..., Bucket]] = {
    "stump_scan": _bucket_stump_scan,
    "stump_scan_batched": _bucket_stump_scan_batched,
    "ensemble_vote": _bucket_ensemble_vote,
    "ensemble_vote_batched": _bucket_vote_batched,
    "stump_vote_batched": _bucket_stump_vote_batched,
    "stump_vote_fp_batched": _bucket_stump_vote_batched,
    "flash_attention": _bucket_flash_attention,
    "dist_update": _bucket_dist_update,
}


def bucket_of(kernel: str, args: Sequence, kwargs: Optional[dict] = None
              ) -> Bucket:
    """The shape bucket one call lowers to (its padded kernel shape)."""
    return _BUCKETERS[kernel](*args, **(kwargs or {}))


# ---------------------------------------------------------------------------
# backends
# ---------------------------------------------------------------------------

class PallasInterpretBackend:
    """Pallas kernels under the interpreter — correct everywhere."""
    name = "interpret"

    def available(self) -> bool:
        return True

    def run(self, kernel: str, *args, **kwargs):
        return _PALLAS_IMPLS[kernel](*args, interpret=True, **kwargs)


class PallasMosaicBackend:
    """Pallas kernels compiled by Mosaic — TPU only."""
    name = "mosaic"

    def available(self) -> bool:
        return on_tpu()

    def run(self, kernel: str, *args, **kwargs):
        return _PALLAS_IMPLS[kernel](*args, interpret=False, **kwargs)


class XlaRefBackend:
    """The jnp oracles, jit-compiled by XLA — the universal fallback."""
    name = "xla"

    def available(self) -> bool:
        return True

    def run(self, kernel: str, *args, **kwargs):
        return _XLA_IMPLS[kernel](*args, **kwargs)


BACKENDS: Dict[str, object] = {b.name: b for b in (
    PallasInterpretBackend(), PallasMosaicBackend(), XlaRefBackend())}

_ALIASES = {"pallas": "interpret", "pallas_interpret": "interpret",
            "pallas_mosaic": "mosaic", "tpu": "mosaic",
            "ref": "xla", "jnp": "xla", "fallback": "xla"}


def canonical(name: str) -> str:
    key = str(name).strip().lower()
    key = _ALIASES.get(key, key)
    if key not in BACKENDS:
        raise KeyError(
            f"unknown kernel backend {name!r}: expected one of "
            f"{sorted(BACKENDS)} (or aliases {sorted(_ALIASES)})")
    return key


def platform_default() -> str:
    return "mosaic" if on_tpu() else "interpret"


def available_backends() -> List[str]:
    return [n for n, b in sorted(BACKENDS.items()) if b.available()]


# ---------------------------------------------------------------------------
# policy
# ---------------------------------------------------------------------------

class KernelPolicy:
    """Per-call backend + layout selection with a shape-bucketed
    calibration table.

    ``backend=`` forces one backend policy-wide (still subject to
    availability).  ``table`` maps ``(kernel, bucket) -> CalEntry`` (bare
    backend-name values are accepted and normalized to layout-less
    entries) — normally filled by :meth:`calibrate_call` or loaded from
    the JSON written by ``benchmarks/backend_matrix.py``.  Backend
    resolution consults, in order: the per-call explicit argument, the
    forced ``backend``, the ``env_var`` environment variable (read on
    every call), the calibration table, then the platform default.  When
    the resolved backend matches a table entry's winner, :func:`dispatch`
    additionally injects the entry's tuned block layout (explicit caller
    layout kwargs always win).

    ``fused_fingerprint`` opts a serving tenant into the one-launch
    ``stump_vote_fp_batched`` path (`serve/engine.py`); the dispatcher
    itself ignores it.

    ``choices`` records the backend actually dispatched per (kernel,
    bucket) and ``layout_choices`` the injected layout; the internal
    dispatch cache is keyed on the full resolution input (including the
    live env value) so repeated same-bucket calls skip re-resolution
    without ever pinning a stale choice.
    """

    def __init__(self, backend: Optional[str] = None,
                 table: Optional[Dict[Tuple[str, Bucket], object]] = None,
                 env_var: Optional[str] = ENV_VAR,
                 fused_fingerprint: bool = False):
        self.backend = canonical(backend) if backend is not None else None
        self.table: Dict[Tuple[str, Bucket], CalEntry] = {}
        for (kern, bucket), value in (table or {}).items():
            self.table[(kern, tuple(bucket))] = _entry(value)
        self.env_var = env_var
        self.fused_fingerprint = bool(fused_fingerprint)
        self.choices: Dict[Tuple[str, Bucket], str] = {}
        self.layout_choices: Dict[Tuple[str, Bucket], Layout] = {}
        self.cache_hits = 0
        self._cache: Dict[tuple, object] = {}
        self._warned: set = set()
        # platform the loaded calibration table was measured on (None for
        # in-process tables; set by load())
        self.measured_on: Optional[str] = None

    # ------------------------------------------------------------ resolve
    def _env_backend(self) -> Optional[str]:
        if not self.env_var:
            return None
        return os.environ.get(self.env_var) or None

    def resolve_name(self, kernel: str, bucket: Bucket, *,
                     explicit: Optional[str] = None) -> str:
        """Backend name for one (kernel, bucket) call, skipping candidates
        whose substrate is unavailable on the current platform."""
        bucket = tuple(bucket)
        entry = self.table.get((kernel, bucket))
        for cand in (explicit, self.backend, self._env_backend(),
                     entry.backend if entry is not None else None):
            if cand is None:
                continue
            name = canonical(cand)
            if BACKENDS[name].available():
                return name
            if name not in self._warned:
                self._warned.add(name)
                warnings.warn(
                    f"kernel backend '{name}' is unavailable on "
                    f"'{jax.default_backend()}'; falling back",
                    RuntimeWarning, stacklevel=3)
        return platform_default()

    def resolve(self, kernel: str, bucket: Bucket, *,
                explicit: Optional[str] = None):
        """Backend object for one call, via the dispatch cache.  The key
        includes every resolution input — the live env value *and* the
        platform — so an env change or TPU hot-attach is never masked by
        a stale cached choice."""
        bucket = tuple(bucket)
        key = (kernel, bucket, explicit, self.backend, self._env_backend(),
               jax.default_backend())
        hit = self._cache.get(key)
        if hit is None:
            hit = BACKENDS[self.resolve_name(kernel, bucket,
                                             explicit=explicit)]
            self._cache[key] = hit
        else:
            self.cache_hits += 1
        self.choices[(kernel, bucket)] = hit.name
        return hit

    # ------------------------------------------------------------- layout
    def layout_for(self, kernel: str, bucket: Bucket, backend: str
                   ) -> Layout:
        """The tuned block layout for one (kernel, bucket) — only if the
        table's winning backend matches the one actually resolved (a tuned
        layout measured for one substrate says nothing about another)."""
        entry = self.table.get((kernel, tuple(bucket)))
        if entry is not None and entry.backend == backend and entry.layout:
            return dict(entry.layout)
        return {}

    # -------------------------------------------------------- calibration
    def record(self, kernel: str, bucket: Bucket, backend: str,
               layout: Optional[Layout] = None) -> None:
        self.table[(kernel, tuple(bucket))] = CalEntry(canonical(backend),
                                                       layout_key(layout))
        self._cache.clear()

    def calibrate_call(self, kernel: str, *args, reps: int = 5,
                       backends: Optional[Sequence[str]] = None,
                       layouts: Optional[Sequence[Layout]] = None, **kwargs
                       ) -> Tuple[Bucket, Dict[Tuple[str, LayoutKey],
                                               List[float]]]:
        """Time every available backend over the kernel's layout grid (one
        compile/warm-up launch per candidate, then ``reps`` timed
        launches), record the ``(backend, layout)`` median winner for the
        call's bucket, and return ``(bucket, {(backend, layout_key):
        [seconds]})``.

        Pallas backends sweep ``layouts`` (default: the kernel's
        ``LAYOUT_GRIDS`` entry); the ``xla`` oracle has no block layout
        and is measured once with an empty layout."""
        bucket = bucket_of(kernel, args, kwargs)
        base = {k: v for k, v in kwargs.items()
                if k not in LAYOUT_KWARGS and v is not None}
        samples: Dict[Tuple[str, LayoutKey], List[float]] = {}
        for name in (backends if backends is not None else sorted(BACKENDS)):
            be = BACKENDS[canonical(name)]
            if not be.available():
                continue
            if be.name == "xla":
                grid: Sequence[Layout] = [{}]
            elif layouts is not None:
                grid = list(layouts)
            else:
                grid = LAYOUT_GRIDS.get(kernel, [{}])
            for layout in grid:
                call_kwargs = dict(base, **layout)
                jax.block_until_ready(be.run(kernel, *args, **call_kwargs))
                ts = []
                for _ in range(reps):
                    t0 = time.perf_counter()
                    jax.block_until_ready(
                        be.run(kernel, *args, **call_kwargs))
                    ts.append(time.perf_counter() - t0)
                samples[(be.name, layout_key(layout))] = ts
        if not samples:
            raise ValueError(
                f"no backend to calibrate {kernel!r}: none of "
                f"{list(backends) if backends is not None else sorted(BACKENDS)} "
                f"is available on '{jax.default_backend()}' "
                f"(available: {available_backends()})")
        wname, wlayout = min(
            samples, key=lambda k: statistics.median(samples[k]))
        self.record(kernel, bucket, wname, dict(wlayout))
        return bucket, samples

    # -------------------------------------------------------- persistence
    def save(self, path: str = DEFAULT_CALIBRATION_PATH,
             measured_on: Optional[str] = None) -> str:
        """Persist the calibration table (JSON, schema v2: every entry
        carries its winning backend *and* block layout, and the table
        records the platform it was measured on) so restarts skip
        recalibration; returns the path written."""
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        data = {
            "version": CALIBRATION_SCHEMA_VERSION,
            "env_var": self.env_var,
            "backend": self.backend,
            "measured_on": (measured_on if measured_on is not None
                            else jax.default_backend()),
            "table": [{"kernel": k, "bucket": list(b), "backend": e.backend,
                       "layout": dict(e.layout)}
                      for (k, b), e in sorted(self.table.items())],
        }
        p.write_text(json.dumps(data, indent=2) + "\n")
        return str(p)

    @classmethod
    def load(cls, path: str = DEFAULT_CALIBRATION_PATH) -> "KernelPolicy":
        """Load a persisted table.  Schema v1 (backend-only entries, no
        ``version`` field) loads transparently with empty layouts — the
        reference ``DEFAULT_LAYOUTS`` then apply at dispatch time.  A
        table measured on a different platform warns once per process:
        its tuned layouts still load (they are only hints) but say
        nothing about this substrate — re-run benchmarks.backend_matrix
        here to re-measure."""
        data = json.loads(Path(path).read_text())
        version = int(data.get("version", 1))
        if version > CALIBRATION_SCHEMA_VERSION:
            raise ValueError(
                f"calibration table {path!r} has schema v{version}; this "
                f"build reads up to v{CALIBRATION_SCHEMA_VERSION}")
        measured_on = data.get("measured_on")
        platform = jax.default_backend()
        if (measured_on and measured_on != platform
                and data.get("table")
                and (measured_on, platform) not in _PLATFORM_WARNED):
            _PLATFORM_WARNED.add((measured_on, platform))
            warnings.warn(
                f"calibration table {path!r} was measured on "
                f"'{measured_on}' but this process runs on '{platform}'; "
                f"its tuned (backend, layout) winners may not transfer — "
                f"re-run `python -m benchmarks.backend_matrix` on this "
                f"platform to re-measure", RuntimeWarning, stacklevel=2)
        pol = cls(backend=data.get("backend"),
                  table={(e["kernel"], tuple(e["bucket"])):
                         CalEntry(canonical(e["backend"]),
                                  layout_key(e.get("layout")))
                         for e in data.get("table", [])},
                  env_var=data.get("env_var", ENV_VAR))
        pol.measured_on = measured_on
        return pol


_DEFAULT_POLICY = KernelPolicy()


def default_policy() -> KernelPolicy:
    """The process-wide policy used when no ``policy=`` is passed."""
    return _DEFAULT_POLICY


def set_default_policy(policy: KernelPolicy) -> KernelPolicy:
    """Swap the process-wide default policy; returns the previous one."""
    global _DEFAULT_POLICY
    old, _DEFAULT_POLICY = _DEFAULT_POLICY, policy
    return old


# ---------------------------------------------------------------------------
# dispatch entry (the single funnel behind every ops.py wrapper)
# ---------------------------------------------------------------------------

def _with_layout(kernel: str, kwargs: dict, pol: "KernelPolicy",
                 bucket: Bucket, backend_name: str) -> dict:
    """Resolve the block layout for one call: explicit caller kwargs win
    over the calibration table's tuned layout, which wins over the
    reference ``DEFAULT_LAYOUTS``.  ``None`` layout kwargs (the ops
    wrappers' "let the table decide" default) are stripped."""
    kwargs = dict(kwargs)
    explicit: Layout = {}
    for k in LAYOUT_KWARGS:
        if k in kwargs:
            v = kwargs.pop(k)
            if v is not None:
                explicit[k] = int(v)
    layout = dict(DEFAULT_LAYOUTS.get(kernel, {}))
    layout.update(pol.layout_for(kernel, bucket, backend_name))
    layout.update(explicit)
    kwargs.update(layout)
    pol.layout_choices[(kernel, tuple(bucket))] = layout
    return kwargs


def dispatch(kernel: str, args: Sequence, kwargs: Optional[dict] = None, *,
             policy: Optional[KernelPolicy] = None,
             backend: Optional[str] = None,
             interpret: Optional[bool] = None):
    """Resolve a backend + block layout for this call and run it.

    ``interpret`` is the deprecated bool shim: True maps to the
    ``interpret`` backend, False to ``mosaic`` (which falls back to the
    platform default where Mosaic is unavailable).
    """
    kwargs = dict(kwargs or {})
    if interpret is not None:
        warnings.warn(
            "interpret= is deprecated; pass backend='interpret'/'mosaic'/"
            "'xla' or a KernelPolicy", DeprecationWarning, stacklevel=3)
        if backend is None:
            backend = "interpret" if interpret else "mosaic"
    pol = policy if policy is not None else _DEFAULT_POLICY
    bucket = bucket_of(kernel, args, kwargs)
    be = pol.resolve(kernel, bucket, explicit=backend)
    kwargs = _with_layout(kernel, kwargs, pol, bucket, be.name)
    if not obs.profiling_enabled():
        return be.run(kernel, *args, **kwargs)
    # profiling path: timing a launch requires blocking on the device, so
    # this only runs while obs profiling is switched on
    blabel = bucket_label(bucket)
    with obs.span(f"kernel.{kernel}", backend=be.name, bucket=blabel):
        t0 = time.perf_counter()
        out = jax.block_until_ready(be.run(kernel, *args, **kwargs))
        dt = time.perf_counter() - t0
    reg = obs.get_registry()
    labels = dict(kernel=kernel, bucket=blabel, backend=be.name)
    # the first profiled launch of a (kernel, bucket, backend) pays jit
    # trace/compile inside the blocked region — keep it out of the
    # steady-state wall_s histogram (calibration_check reads p50s there)
    seen = getattr(reg, "_kernel_seen", None)
    if seen is None:
        seen = set()
        setattr(reg, "_kernel_seen", seen)
    first = (kernel, blabel, be.name) not in seen
    seen.add((kernel, blabel, be.name))
    reg.counter("kernel.launches", **labels).inc()
    if first:
        reg.histogram("kernel.compile_s", **labels).observe(dt)
    else:
        reg.histogram("kernel.wall_s", **labels).observe(dt)
    return out


def bucket_label(bucket: Bucket) -> str:
    """Render a shape bucket as a metrics label ("256x8x8")."""
    return "x".join(str(int(d)) for d in bucket)


def calibration_check(policy: Optional[KernelPolicy] = None,
                      registry=None, *, min_count: int = 5
                      ) -> List[Dict[str, object]]:
    """Sanity-check the calibration table against *observed* launch timings.

    For every (kernel, bucket) the policy has a calibrated winner for,
    compare the winner's observed p50 wall time (from the
    ``kernel.wall_s{kernel,bucket,backend}`` histograms that profiled
    dispatches record; first-launch compile times land in
    ``kernel.compile_s`` and never skew this) against every other backend
    observed on the same bucket.  Backends with fewer than ``min_count``
    steady-state observations are ignored entirely — a single stray
    sample must not outvote a calibrated winner.  Returns one flag dict
    per entry where a non-winner was measurably faster (including the
    per-backend observation ``counts``) — i.e. the persisted calibration
    no longer matches live behavior and a recalibration pass is
    warranted.  Entries with no cross-backend observations are skipped,
    not flagged."""
    pol = policy if policy is not None else _DEFAULT_POLICY
    reg = registry if registry is not None else obs.get_registry()
    min_count = max(1, int(min_count))
    observed: Dict[Tuple[str, str], Dict[str, object]] = {}
    for name, labels, h in reg.histograms():
        if name != "kernel.wall_s" or h.count < min_count:
            continue
        key = (labels.get("kernel", ""), labels.get("bucket", ""))
        observed.setdefault(key, {})[labels.get("backend", "")] = h
    flags: List[Dict[str, object]] = []
    for (kern, bucket), entry in sorted(pol.table.items()):
        winner = entry.backend
        hists = observed.get((kern, bucket_label(bucket)))
        if not hists or winner not in hists or len(hists) < 2:
            continue
        best = min(hists, key=lambda b: hists[b].p50)
        if best != winner and hists[best].p50 < hists[winner].p50:
            flags.append({
                "kernel": kern, "bucket": bucket_label(bucket),
                "calibrated": winner,
                "calibrated_p50_s": hists[winner].p50,
                "observed_best": best,
                "observed_best_p50_s": hists[best].p50,
                "counts": {b: hists[b].count for b in sorted(hists)},
            })
    return flags
