"""Pallas TPU kernel: blockwise (flash) causal attention with online
softmax — the optimized prefill path for the 32k-sequence shapes.

TPU adaptation (DESIGN.md §4): a GPU flash kernel stages K/V tiles through
shared memory per thread-block; here the grid is (batch*heads, q-blocks,
k-blocks) with the k-block dimension innermost ("arbitrary" semantics —
sequential on core), carrying the running max / denominator / accumulator
in VMEM scratch across k-steps.  Block shapes default to (128, 128), MXU-
aligned; head_dim is padded to 128 lanes by the ops wrapper.

Causality is handled by masking inside the tile (fully-masked tiles are
still visited; the cost model in benchmarks/kernel_bench.py accounts the
factor-2 overhead vs a block-skipping schedule, a known trade-off of
rectangular grids).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, block_q: int, block_k: int, causal: bool):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)              # (bq, d)
    k = k_ref[0].astype(jnp.float32)              # (bk, d)
    v = v_ref[0].astype(jnp.float32)              # (bk, d)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                       (block_q, block_k), 0)
        kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                       (block_q, block_k), 1)
        s = jnp.where(qpos >= kpos, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    correction = jnp.exp(m_prev - m_new)
    l_new = correction * l_ref[...] + jnp.sum(p, axis=1)
    acc_ref[...] = (acc_ref[...] * correction[:, None]
                    + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                          preferred_element_type=jnp.float32))
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "block_q", "block_k",
                                    "interpret"))
def flash_attention_kernel(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                           causal: bool = True, block_q: int = 128,
                           block_k: int = 128,
                           interpret: bool = True) -> jnp.ndarray:
    """q,k,v: (BH, T, d) -> (BH, T, d).  T divisible by both block sizes;
    d should be 128-lane padded (ops wrapper handles both)."""
    BH, T, d = q.shape
    assert T % block_q == 0 and T % block_k == 0, (T, block_q, block_k)
    scale = 1.0 / math.sqrt(d)
    grid = (BH, T // block_q, T // block_k)
    kern = functools.partial(_flash_kernel, scale=scale, block_q=block_q,
                             block_k=block_k, causal=causal)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, T, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # running max m
            pltpu.VMEM((block_q,), jnp.float32),      # running denom l
            pltpu.VMEM((block_q, d), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
