# repro.sim — trace-driven client-heterogeneity simulation: the
# ClientBehavior device/link models under the federated engines, the
# scenario registry binding the five paper domains (+ stress variants) to
# partitioners/behavior mixes/paper bands, and the train->serve harness.
#
# The harness is imported lazily (PEP 562): it depends on repro.core and
# repro.serve, while repro.core.async_engine imports repro.sim.behavior —
# eager re-export here would close that cycle.
from repro.sim.behavior import (  # noqa: F401
    BlockchainLedger, BlockDelayBehavior, ClientBehavior, DiurnalBehavior,
    GilbertLinkBehavior, LegacyBehavior, Link, SiteBehavior,
    SiteOutageProcess, TraceSchedule, legacy_behaviors)
from repro.sim.scenarios import (  # noqa: F401
    DOMAINS, PAPER_BANDS, SCENARIOS, PaperBand, Scenario, base_scenarios,
    get_scenario, register, variant_scenarios)

_HARNESS_NAMES = ("ScenarioReport", "run_scenario", "replay_serve",
                  "train_pair", "summarize")


def __getattr__(name: str):
    if name in _HARNESS_NAMES:
        from repro.sim import harness
        return getattr(harness, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
