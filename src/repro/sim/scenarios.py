"""Scenario registry: the five paper domains (and stress variants) bound to
a data partitioner, a client-behavior mix, and paper-band expectations.

This is the single source of truth for "what is the edge-vision domain":
the :class:`~repro.configs.paper_fedboost.DomainConfig` environment, the
partitioner from :mod:`repro.data.partition`, the paper's Table-1 relative
improvement bands, and — new with the simulator — *named behavior traces*
per domain (``legacy`` plus at least two correlated/time-varying mixes).
``benchmarks/domains.py`` and ``examples/fed_healthcare.py`` re-source
their domain tables from here; the old ``configs.paper_fedboost.DOMAINS``
and ``benchmarks.domains.PAPER_BANDS`` names remain as deprecation shims
for one release.

A *trace factory* maps ``(domain, seed) -> behavior_for`` where
``behavior_for(cid)`` builds one :class:`ClientBehavior` per client; the
``legacy`` trace returns ``None`` so the engine installs its bit-for-bit
:class:`LegacyBehavior` shim.  Factories are called freshly per engine run
— stateful behaviors (Gilbert chains, outage processes) must never be
shared between a baseline and an enhanced run.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Mapping, Optional, Tuple

import numpy as np

from repro.configs.paper_fedboost import (
    CompensationConfig, DomainConfig, FedBoostConfig, SchedulerConfig)
from repro.sim.behavior import (
    BlockchainLedger, BlockDelayBehavior, ClientBehavior, DiurnalBehavior,
    GilbertLinkBehavior, Link, SiteBehavior, SiteOutageProcess,
    TraceSchedule)

BehaviorFor = Callable[[int], ClientBehavior]
TraceFactory = Callable[[DomainConfig, int], Optional[BehaviorFor]]


# ------------------------------------------------------------- paper bands
@dataclass(frozen=True)
class PaperBand:
    """Table-1 relative-improvement bands (enhanced vs baseline), as
    (low, high) percent ranges; ``acc_delta_pp`` in percentage points.
    ``check`` asserts against the band floor minus a reproduction
    tolerance (small-seed, short-run reproductions sit inside the band on
    average but individual runs need slack)."""
    time_down: Tuple[float, float]
    comm_down: Tuple[float, float]
    conv_down: Tuple[float, float]
    acc_delta_pp: Tuple[float, float]
    tol_time: float = 12.0
    tol_comm: float = 8.0
    tol_acc: float = 2.0

    @property
    def midpoints(self) -> Tuple[float, float, float, float]:
        return tuple(0.5 * (lo + hi) for lo, hi in
                     (self.time_down, self.comm_down, self.conv_down,
                      self.acc_delta_pp))

    def check(self, row: Mapping[str, float]) -> List[str]:
        """Band-compliance failures for one {time_down, comm_down,
        acc_delta_pp} result row (empty = within band)."""
        fails = []
        floor = self.time_down[0] - self.tol_time
        if row["time_down"] < floor:
            fails.append(f"time_down {row['time_down']:.1f}% < {floor:.0f}%")
        floor = self.comm_down[0] - self.tol_comm
        if row["comm_down"] < floor:
            fails.append(f"comm_down {row['comm_down']:.1f}% < {floor:.0f}%")
        floor = self.acc_delta_pp[0] - self.tol_acc
        if row["acc_delta_pp"] < floor:
            fails.append(
                f"acc_delta {row['acc_delta_pp']:+.1f}pp < {floor:+.1f}pp")
        return fails


# --------------------------------------------------------------- scenarios
@dataclass(frozen=True)
class Scenario:
    """One registered deployment scenario: environment + partitioner +
    behavior traces + expectations."""
    name: str
    domain: DomainConfig
    band: PaperBand
    traces: Mapping[str, TraceFactory]
    partitioner: str = "dirichlet"          # iid | dirichlet | label_shard
    shards_per_client: int = 2              # label_shard knob
    n_rounds: int = 20                      # default boosting rounds
    serve_rate: float = 400.0               # replay nominal request rate
    time_warp: float = 20.0                 # behavior-seconds per serve-second
    variant_of: Optional[str] = None        # base scenario for variants
    notes: str = ""
    serve_replay: bool = True               # replay the serve phase at all?
    # engine profile: None auto-selects (FLEET_AUTO_CLIENTS); True forces
    # the vectorized fleet profile (repro.core.fleet)
    fleet: Optional[bool] = None
    # extra make_domain_data kwargs (val_frac/test_frac/as_numpy — the
    # fleet scenarios shrink the held-out sets and skip jnp conversion)
    data_kwargs: Mapping = field(default_factory=dict)
    # FedBoostConfig field overrides applied after construction
    # (catch_up_cap, compensation, scheduler, ...)
    config_overrides: Mapping = field(default_factory=dict)
    # decentralized chain-of-record mode: the harness backs the serving
    # fleet with a repro.chain.ChainCluster (publishes commit to a shared
    # chain; no central registry instance) instead of a ShardCluster
    chain: bool = False

    def make_data(self, seed: int = 0) -> Dict:
        from repro.data import make_domain_data
        return make_domain_data(self.domain, seed=seed,
                                partitioner=self.partitioner,
                                shards_per_client=self.shards_per_client,
                                **dict(self.data_kwargs))

    def fedboost_config(self, seed: int = 0,
                        n_rounds: Optional[int] = None) -> FedBoostConfig:
        dom = self.domain
        cfg = FedBoostConfig(
            n_clients=dom.n_clients,
            n_rounds=self.n_rounds if n_rounds is None else n_rounds,
            straggler_factor=dom.straggler_factor,
            dropout_prob=dom.dropout_prob, link_mbps=dom.link_mbps,
            seed=seed, balanced_init=dom.label_imbalance < 0.4)
        if self.config_overrides:
            cfg = replace(cfg, **dict(self.config_overrides))
        return cfg

    def behavior_for(self, trace: str, seed: int = 0
                     ) -> Optional[BehaviorFor]:
        """A fresh ``behavior_for`` hook for one engine run (or None for
        the legacy shim)."""
        if trace not in self.traces:
            raise KeyError(
                f"scenario {self.name!r} has no trace {trace!r}; "
                f"choose from {sorted(self.traces)}")
        return self.traces[trace](self.domain, seed)

    @property
    def nontrivial_traces(self) -> List[str]:
        return sorted(t for t in self.traces if t != "legacy")


SCENARIOS: Dict[str, Scenario] = {}


def register(sc: Scenario) -> Scenario:
    if sc.name in SCENARIOS:
        raise ValueError(f"scenario {sc.name!r} already registered")
    SCENARIOS[sc.name] = sc
    return sc


def get_scenario(name: str) -> Scenario:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"registered: {sorted(SCENARIOS)}") from None


def base_scenarios() -> List[str]:
    """The five paper domains, registry order."""
    return [n for n, s in SCENARIOS.items() if s.variant_of is None]


def variant_scenarios() -> List[str]:
    return [n for n, s in SCENARIOS.items() if s.variant_of is not None]


# ------------------------------------------------------- behavior factories
def _speeds(dom: DomainConfig, seed: int, tag: int) -> np.ndarray:
    """Per-client compute multipliers ~ LogUniform[1, straggler_factor],
    from a trace-local RNG (never the engine's — only the legacy shim may
    touch that stream)."""
    rng = np.random.RandomState(seed * 7919 + tag)
    return np.exp(rng.uniform(0.0, math.log(max(dom.straggler_factor, 1.0)),
                              size=dom.n_clients))


def _legacy(dom: DomainConfig, seed: int) -> None:
    return None             # engine installs the bit-for-bit scalar shim


def _diurnal(peak=0.95, trough=0.35, night_slowdown=1.5, period_s=24.0
             ) -> TraceFactory:
    """Phones on a day/night cycle, phases staggered across the fleet so
    availability is correlated-but-not-identical (time zones, habits)."""
    def make(dom: DomainConfig, seed: int) -> BehaviorFor:
        speeds = _speeds(dom, seed, 11)
        rng = np.random.RandomState(seed * 7919 + 12)
        phases = rng.uniform(0.0, period_s / 4.0, size=dom.n_clients)
        behaviors = [DiurnalBehavior(
            float(speeds[c]), period_s, float(phases[c]),
            np.random.RandomState(seed * 7919 + 100 + c),
            peak=peak, trough=trough, night_slowdown=night_slowdown,
            link_mbps=dom.link_mbps) for c in range(dom.n_clients)]
        return lambda cid: behaviors[cid]
    return make


def _gilbert(mean_good_s=8.0, mean_bad_s=2.0, drop_in_bad=0.6,
             bad_bw_frac=0.05, bad_latency_s=0.5) -> TraceFactory:
    """Bursty on/off radio links (Gilbert-Elliott): deep fades arrive in
    runs, not i.i.d. coin flips."""
    def make(dom: DomainConfig, seed: int) -> BehaviorFor:
        speeds = _speeds(dom, seed, 21)
        behaviors = [GilbertLinkBehavior(
            float(speeds[c]), np.random.RandomState(seed * 7919 + 200 + c),
            mean_good_s=mean_good_s, mean_bad_s=mean_bad_s,
            good=Link(0.05, dom.link_mbps),
            bad=Link(bad_latency_s, dom.link_mbps * bad_bw_frac),
            drop_in_bad=drop_in_bad) for c in range(dom.n_clients)]
        return lambda cid: behaviors[cid]
    return make


def _site_outage(clients_per_site=4, mean_up_s=20.0, mean_down_s=4.0
                 ) -> TraceFactory:
    """Correlated multi-client outages: clients grouped into sites (edge
    racks, hospital wings) that fail *together* — Poisson outage arrivals,
    exponential repair times, shared by every client on the site."""
    def make(dom: DomainConfig, seed: int) -> BehaviorFor:
        speeds = _speeds(dom, seed, 31)
        n_sites = max(1, dom.n_clients // clients_per_site)
        sites = [SiteOutageProcess(
            np.random.RandomState(seed * 7919 + 300 + s),
            mean_up_s=mean_up_s, mean_down_s=mean_down_s)
            for s in range(n_sites)]
        behaviors = [SiteBehavior(sites[c % n_sites], float(speeds[c]),
                                  link_mbps=dom.link_mbps)
                     for c in range(dom.n_clients)]
        return lambda cid: behaviors[cid]
    return make


def _block_delay(block_interval_s=0.4, confirmations=2, congestion_prob=0.1,
                 congestion_blocks=3,
                 commits_per_block=1) -> TraceFactory:
    """Blockchain peers: every uplink waits for inclusion on a *shared*
    ledger (commits serialize on block capacity — a synchronous round's
    burst of K commits queues ~K blocks deep) + confirmations, with
    occasional fee-market congestion spikes."""
    def make(dom: DomainConfig, seed: int) -> BehaviorFor:
        speeds = _speeds(dom, seed, 41)
        ledger = BlockchainLedger(np.random.RandomState(seed * 7919 + 499),
                                  block_interval_s=block_interval_s,
                                  commits_per_block=commits_per_block)
        behaviors = [BlockDelayBehavior(
            float(speeds[c]), np.random.RandomState(seed * 7919 + 400 + c),
            block_interval_s=block_interval_s, confirmations=confirmations,
            congestion_prob=congestion_prob,
            congestion_blocks=congestion_blocks,
            link_mbps=dom.link_mbps, fork_drop=dom.dropout_prob,
            ledger=ledger)
            for c in range(dom.n_clients)]
        return lambda cid: behaviors[cid]
    return make


# A recorded-trace example: a 12-simulated-second battery/duty cycle as it
# would come back from a fleet-telemetry dump.  Replayed (looped) through
# TraceSchedule over the per-client compute multiplier — this is the JSON
# shape ``TraceSchedule.from_json`` accepts from a file too.
BATTERY_TRACE_JSON: Dict = {
    "loop_s": 12.0,
    "segments": [
        {"t": 0.0, "available": True, "speed": 1.0},
        {"t": 5.0, "available": True, "speed": 2.5,          # battery saver
         "bandwidth_mbps": 1.0},
        {"t": 8.0, "available": False},                      # deep sleep
        {"t": 10.0, "available": True, "speed": 1.2},
    ],
}

DUTY_CYCLE_TRACE_JSON: Dict = {
    "loop_s": 8.0,
    "segments": [
        {"t": 0.0, "available": True},
        {"t": 5.5, "available": False},   # sensor sleeps 30% of each cycle
    ],
}


def _recorded_trace(name: str, stagger_s: float = 0.0,
                    base: Optional[TraceFactory] = None) -> TraceFactory:
    """Replay a checked-in ``artifacts/traces/<name>.json`` recording per
    client (loaded lazily, so registering the scenario never requires the
    artifacts directory to exist)."""
    def make(dom: DomainConfig, seed: int) -> BehaviorFor:
        from repro.sim.traces import load_trace
        return _trace_replay(load_trace(name), stagger_s=stagger_s,
                             base=base)(dom, seed)
    return make


def _trace_replay(trace_json: Dict, stagger_s: float = 0.0,
                  base: Optional[TraceFactory] = None) -> TraceFactory:
    """Replay a recorded JSON trace per client (optionally staggering each
    client's phase within the loop, and optionally layered over another
    factory's behaviors)."""
    def make(dom: DomainConfig, seed: int) -> BehaviorFor:
        base_for = base(dom, seed) if base is not None else None
        speeds = _speeds(dom, seed, 51)

        def build(cid: int) -> ClientBehavior:
            inner = (base_for(cid) if base_for is not None else
                     _ConstantBehavior(float(speeds[cid]), dom.link_mbps))
            return TraceSchedule.from_json(trace_json, base=inner,
                                           phase_s=cid * stagger_s)
        behaviors = [build(c) for c in range(dom.n_clients)]
        return lambda cid: behaviors[cid]
    return make


class _ConstantBehavior(ClientBehavior):
    """Deterministic straggler: fixed speed + link, always available."""

    def __init__(self, speed: float, link_mbps: float,
                 latency_s: float = 0.05):
        self.speed = float(speed)
        self._link = Link(latency_s, link_mbps)

    def compute_time(self, work: float, t: float = 0.0) -> float:
        return work * self.speed

    def link(self, t: float) -> Link:
        return self._link


def _staggered_join(join_gap_s: float = 4.0) -> TraceFactory:
    """Cold start: client ``cid`` only comes online at ``cid * join_gap_s``
    (fleet rollout / enrollment ramp)."""
    def make(dom: DomainConfig, seed: int) -> BehaviorFor:
        speeds = _speeds(dom, seed, 61)

        def build(cid: int) -> ClientBehavior:
            inner = _ConstantBehavior(float(speeds[cid]), dom.link_mbps)
            return TraceSchedule(
                [{"t": 0.0, "available": False},
                 {"t": cid * join_gap_s, "available": True}], base=inner)
        behaviors = [build(c) for c in range(dom.n_clients)]
        return lambda cid: behaviors[cid]
    return make


# ------------------------------------------------------------ the registry
# Environment tables carried over verbatim from the old ad-hoc
# configs.paper_fedboost.DOMAINS dict — that name now shims onto these.
register(Scenario(
    name="edge_vision",
    domain=DomainConfig(
        name="edge_vision", n_samples=4000, n_features=64, n_clients=12,
        noniid_alpha=0.5, label_imbalance=0.5, noise=0.15,
        straggler_factor=5.0, dropout_prob=0.10, link_mbps=8.0),
    band=PaperBand((15, 35), (20, 40), (15, 25), (0.0, 2.0)),
    traces={
        "legacy": _legacy,
        # cameras racked 4-per-switch: whole racks drop together
        "rack_outage": _site_outage(clients_per_site=4,
                                    mean_up_s=18.0, mean_down_s=5.0),
        # shared backhaul congests on a rush-hour cycle
        "rush_hour": _diurnal(peak=0.98, trough=0.6, night_slowdown=1.0,
                              period_s=16.0),
    },
    serve_rate=500.0,
    notes="smart-city cameras, rack-correlated failures"))

register(Scenario(
    name="blockchain",
    domain=DomainConfig(
        name="blockchain", n_samples=5000, n_features=32, n_clients=8,
        noniid_alpha=1.0, label_imbalance=0.45, noise=0.20,
        straggler_factor=2.0, dropout_prob=0.02, link_mbps=2.0),
    band=PaperBand((24, 40), (30, 50), (15, 25), (-0.2, 2.0)),
    traces={
        "legacy": _legacy,
        # every sync waits for block inclusion + 2 confirmations
        "block_delay": _block_delay(block_interval_s=0.4, confirmations=2),
        # fee-market spikes: frequent multi-block congestion delays
        "congestion": _block_delay(block_interval_s=0.4, confirmations=3,
                                   congestion_prob=0.35,
                                   congestion_blocks=5),
    },
    serve_rate=300.0,
    notes="on-chain federated marketplace, confirmation-delayed links"))

register(Scenario(
    name="mobile",
    domain=DomainConfig(
        name="mobile", n_samples=6000, n_features=48, n_clients=32,
        noniid_alpha=0.2, label_imbalance=0.5, noise=0.18,
        straggler_factor=6.0, dropout_prob=0.15, link_mbps=5.0),
    band=PaperBand((14, 30), (17, 37), (10, 20), (-1.0, 2.0)),
    traces={
        "legacy": _legacy,
        # phones on staggered day/night cycles, slower + flakier at night
        "diurnal": _diurnal(peak=0.95, trough=0.3, night_slowdown=1.8,
                            period_s=24.0),
        # recorded battery/duty-cycle telemetry replayed per client
        "battery_trace": _trace_replay(BATTERY_TRACE_JSON, stagger_s=1.7),
        # checked-in diurnal recording (artifacts/traces/mobile_diurnal
        # .json): one reference handset's observed day, staggered per
        # client like a fleet across time zones
        "diurnal_trace": _recorded_trace("mobile_diurnal", stagger_s=1.3),
    },
    serve_rate=800.0,
    notes="keyboard personalization fleet, diurnal availability"))

register(Scenario(
    name="iot",
    domain=DomainConfig(
        name="iot", n_samples=4000, n_features=24, n_clients=24,
        noniid_alpha=0.3, label_imbalance=0.15, noise=0.10,
        straggler_factor=3.0, dropout_prob=0.12, link_mbps=1.0),
    band=PaperBand((12, 28), (15, 35), (10, 20), (-2.0, 2.0)),
    traces={
        "legacy": _legacy,
        # Gilbert-Elliott radio: deep fades arrive in bursts
        "gilbert": _gilbert(mean_good_s=8.0, mean_bad_s=2.0,
                            drop_in_bad=0.6),
        # recorded sensor duty cycle (sleeps 30% of every 8 s) over a
        # milder fading link
        "duty_cycle": _trace_replay(
            DUTY_CYCLE_TRACE_JSON, stagger_s=0.9,
            base=_gilbert(mean_good_s=12.0, mean_bad_s=1.0,
                          drop_in_bad=0.3)),
    },
    serve_rate=600.0,
    notes="anomaly detection on battery sensors, bursty LPWAN links"))

register(Scenario(
    name="healthcare",
    domain=DomainConfig(
        name="healthcare", n_samples=3000, n_features=40, n_clients=6,
        noniid_alpha=0.8, label_imbalance=0.20, noise=0.12,
        straggler_factor=2.5, dropout_prob=0.03, link_mbps=20.0),
    band=PaperBand((9, 25), (15, 35), (15, 25), (0.0, 3.0)),
    traces={
        "legacy": _legacy,
        # hospital wings (2 clients each) share maintenance windows that
        # are waited out, not retried
        "maintenance": _site_outage(clients_per_site=2,
                                    mean_up_s=25.0, mean_down_s=6.0),
        # compute contends with clinical load on a day cycle; the site
        # itself stays up (hospitals run 24/7)
        "night_shift": _diurnal(peak=1.0, trough=0.85, night_slowdown=2.5,
                                period_s=20.0),
    },
    serve_rate=200.0,
    notes="six hospitals, imbalanced diagnoses, maintenance windows"))


# ------------------------------------------------------------ stress variants
_mobile = get_scenario("mobile")
register(replace(
    _mobile, name="mobile_x4", variant_of="mobile",
    domain=replace(_mobile.domain, name="mobile_x4",
                   n_samples=24000, n_clients=128),
    traces={"legacy": _legacy,
            "diurnal": _mobile.traces["diurnal"]},
    serve_rate=1600.0,
    notes="scale-up: 4x the clients and samples of the mobile domain"))

_edge = get_scenario("edge_vision")
register(replace(
    _edge, name="edge_vision_churn", variant_of="edge_vision",
    traces={"legacy": _legacy,
            # adversarial churn: long correlated deep fades with near-total
            # loss — the regime where a sync barrier starves
            "churn": _gilbert(mean_good_s=4.0, mean_bad_s=3.0,
                              drop_in_bad=0.95, bad_bw_frac=0.02,
                              bad_latency_s=1.0)},
    notes="adversarial churn variant of edge_vision"))

# decentralized chain-of-record variant: same environment and paper band
# as the blockchain domain, but the harness backs serving with a
# repro.chain.ChainCluster — publishes commit client deltas to a shared
# hash-linked chain, a rotating committee aggregates confirmed blocks,
# and there is no central registry instance to kill.  The harness also
# kills the committee leader mid-replay; the band and the zero-loss serve
# invariant must hold regardless.
_blockchain = get_scenario("blockchain")
register(replace(
    _blockchain, name="blockchain_flchain", variant_of="blockchain",
    chain=True,
    traces={"legacy": _legacy,
            "block_delay": _blockchain.traces["block_delay"]},
    notes="server-less FLchain mode: chain-of-record replaces the "
          "central registry (arXiv:2112.07938)"))

_iot = get_scenario("iot")
register(replace(
    _iot, name="iot_coldstart", variant_of="iot",
    traces={"legacy": _legacy,
            # enrollment ramp: client k joins at t = 2.5k seconds
            "staggered_join": _staggered_join(join_gap_s=2.5)},
    notes="cold-start variant: clients enroll on a ramp"))

# fleet-scale smoke: 100k phones on tiny shards, driven by the vectorized
# fleet profile (repro.core.fleet).  The band is deliberately loose — the
# scenario exists to exercise event-core + batched-kernel scale (the
# scale_matrix benchmark records wall-clock and band results), not to
# reproduce Table 1, which small shards and capped catch-up cannot.
register(replace(
    _mobile, name="mobile_100k", variant_of="mobile",
    domain=replace(_mobile.domain, name="mobile_100k",
                   n_samples=400_000, n_clients=100_000),
    band=PaperBand((0, 60), (0, 60), (0, 60), (-5.0, 5.0),
                   tol_time=60.0, tol_comm=60.0, tol_acc=10.0),
    traces={"legacy": _legacy,
            "diurnal": _mobile.traces["diurnal"]},
    partitioner="iid",
    n_rounds=4,
    serve_rate=1600.0,
    serve_replay=False, fleet=True,
    data_kwargs={"val_frac": 0.004, "test_frac": 0.004, "as_numpy": True},
    config_overrides={
        "catch_up_cap": 16,                       # O(cap) catch-up per sync
        "compensation": CompensationConfig(decay="hinge"),
        "scheduler": SchedulerConfig(i_init=2),   # 2-round buffers
    },
    notes="fleet-scale smoke: 100k clients, vectorized fleet profile"))


# --------------------------------------------------- legacy-name exports
#: Canonical per-domain environment table (supersedes the old ad-hoc
#: ``configs.paper_fedboost.DOMAINS`` dict, which now shims onto this).
DOMAINS: Dict[str, DomainConfig] = {
    n: SCENARIOS[n].domain for n in base_scenarios()}

#: Table-1 band midpoints keyed by domain — the shape the old
#: ``benchmarks.domains.PAPER_BANDS`` table had.
PAPER_BANDS: Dict[str, Tuple[float, float, float, float]] = {
    n: SCENARIOS[n].band.midpoints for n in base_scenarios()}
