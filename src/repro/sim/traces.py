"""Recorded behavior traces: first-class JSON trace files under
``artifacts/traces/``.

The scenario registry used to embed its example traces as inline dicts;
real deployments record them (FLGo's phone simulator derives availability
from a mobile-usage pings dataset the same way).  This module is the
bridge: ``load_trace(name)`` reads a checked-in JSON trace for
:meth:`TraceSchedule.from_json`, and ``derive_diurnal_trace`` regenerates
the shipped ``mobile_diurnal`` recording — one reference handset observed
over a 24 s simulated day, sampled from the analytic
:class:`~repro.sim.behavior.DiurnalBehavior` model at a fixed seed so the
artifact is reproducible bit for bit (``python -m repro.sim.traces``
rewrites it).
"""
from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Dict, List

import numpy as np

from repro.sim.behavior import DiurnalBehavior

#: Override with REPRO_TRACES_DIR; default is the repo's artifacts dir.
DEFAULT_TRACES_DIR = (Path(__file__).resolve().parents[3]
                      / "artifacts" / "traces")


def traces_dir() -> Path:
    return Path(os.environ.get("REPRO_TRACES_DIR", DEFAULT_TRACES_DIR))


def trace_path(name: str) -> Path:
    return traces_dir() / f"{name}.json"


def available_traces() -> List[str]:
    d = traces_dir()
    return sorted(p.stem for p in d.glob("*.json")) if d.is_dir() else []


def load_trace(name: str) -> Dict:
    """One recorded trace as the dict :meth:`TraceSchedule.from_json`
    accepts (extra metadata keys like ``source`` ride along unharmed)."""
    p = trace_path(name)
    if not p.is_file():
        raise FileNotFoundError(
            f"no recorded trace {name!r} under {traces_dir()} "
            f"(available: {available_traces()})")
    return json.loads(p.read_text())


def derive_diurnal_trace(period_s: float = 24.0, n_segments: int = 48,
                         seed: int = 7, *, peak: float = 0.95,
                         trough: float = 0.3, night_slowdown: float = 1.8,
                         link_mbps: float = 5.0) -> Dict:
    """Record one reference device's day: sample a seeded
    :class:`DiurnalBehavior` every ``period_s / n_segments`` seconds and
    log what a telemetry agent would see — on/off (the Bernoulli
    availability draw, observed not idealized), the compute slowdown, and
    the link bandwidth.  Floats are rounded so the JSON artifact
    round-trips exactly."""
    beh = DiurnalBehavior(1.0, float(period_s), 0.0,
                          np.random.RandomState(seed), peak=peak,
                          trough=trough, night_slowdown=night_slowdown,
                          link_mbps=link_mbps)
    step = float(period_s) / int(n_segments)
    segments = []
    for i in range(int(n_segments)):
        t = i * step
        segments.append({
            "t": round(t, 6),
            "available": bool(beh.availability(t)),
            "speed": round(beh.compute_time(1.0, t), 6),
            "bandwidth_mbps": round(beh.link(t).bandwidth_mbps, 6),
        })
    return {
        "source": (f"derived: DiurnalBehavior(period_s={period_s}, "
                   f"peak={peak}, trough={trough}, "
                   f"night_slowdown={night_slowdown}, seed={seed}) "
                   f"sampled at {n_segments} points over one cycle"),
        "loop_s": float(period_s),
        "segments": segments,
    }


def write_trace(name: str, trace: Dict) -> Path:
    p = trace_path(name)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(trace, indent=1) + "\n")
    return p


if __name__ == "__main__":
    path = write_trace("mobile_diurnal", derive_diurnal_trace())
    print(f"wrote {path}")
