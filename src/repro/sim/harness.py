"""Scenario harness: one registered scenario end to end — train both
engine modes through a behavior trace, then replay the resulting
publish/request trace into the serving fleet.

Training drives :class:`~repro.core.async_engine.FederatedBoostEngine`
(baseline and enhanced) with the scenario's ``behavior_for`` hook; the
enhanced run publishes snapshots mid-training into a
:class:`~repro.serve.shard.ShardCluster` (stamped with the simulated
clock).  The serve phase gossip-converges the cluster, rebases the
publisher clocks, and replays a request trace *derived from the same
behavior models* — each client emits Poisson requests thinned by its
availability and delayed by its link latency (an offline phone sends
nothing; a congested chain peer's requests arrive late) — through a
:class:`~repro.serve.service.ShardedEnsembleServer` under the eq.-(1)
:class:`~repro.serve.autoscale.FleetAutoscaler`.  One
:class:`ScenarioReport` per (scenario, trace, seed) carries both halves.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro import obs
from repro.obs.slo import SLObjective, SLOMonitor
from repro.core import FederatedBoostEngine
from repro.core.async_engine import RunMetrics
from repro.core.metrics import common_target, pct_reduction, time_to_error
from repro.serve import (AutoscaleConfig, BatchConfig, FleetAutoscaler,
                         GossipConfig, ShardCluster, ShardedEnsembleServer)
from repro.sim.scenarios import Scenario, get_scenario

# serve-replay fleet defaults: small fleet, autoscalable, analytic service
# model (same c0 + c1*n regime as benchmarks/autoscale_load)
SERVE_BATCH = BatchConfig(queue_budget=64, max_batch=16, cache_capacity=1024)


def _autoscale_config(n_hosts: int) -> AutoscaleConfig:
    # the caller's fleet size is the floor (like serve_ensemble) — the
    # autoscaler may grow the fleet, never drain below what was asked for
    return AutoscaleConfig(min_hosts=n_hosts, max_hosts=max(6, n_hosts),
                           target_queue=16.0, target_p99_s=0.10,
                           adapt_every_s=0.02, step_down=0.1)


def _service_model(n_kernel: int) -> float:
    return 1.2e-3 + 4.0e-4 * n_kernel


@dataclass
class ScenarioReport:
    """Train->serve results for one (scenario, trace, seed)."""
    scenario: str
    trace: str
    seed: int
    baseline: RunMetrics
    enhanced: RunMetrics
    row: Dict[str, float]            # Table-1-style relative improvements
    band_failures: List[str]         # empty = within paper band
    serve: Optional[Dict] = None     # serving-replay summary (None = skipped)

    @property
    def within_band(self) -> bool:
        return not self.band_failures


def train_pair(sc: Scenario, trace: str, seed: int = 0,
               n_rounds: Optional[int] = None,
               cluster: Optional[ShardCluster] = None,
               publish_every: int = 2, engine: str = "events"
               ) -> Tuple[Dict, Dict[str, RunMetrics]]:
    """Run baseline + enhanced through one behavior trace on one dataset.
    The enhanced engine publishes into ``cluster`` (when given) so the
    serve phase replays real mid-training snapshots.  ``engine`` selects
    the execution core (``events``, the default, or the legacy ``loop``
    parity oracle); the scenario's ``fleet`` field picks the engine
    profile (None = auto by fleet size)."""
    data = sc.make_data(seed)
    cfg = sc.fedboost_config(seed=seed, n_rounds=n_rounds)
    runs: Dict[str, RunMetrics] = {}
    for mode in ("baseline", "enhanced"):
        # a fresh behavior set per engine: stateful models (Gilbert
        # chains, outage processes) must not leak state across runs
        eng = FederatedBoostEngine(cfg, data, mode,
                                   behavior_for=sc.behavior_for(trace, seed),
                                   engine=engine, fleet=sc.fleet)
        if mode == "enhanced" and cluster is not None:
            eng.attach_registry(cluster, sc.name, publish_every=publish_every)
        # traced runs carry contribution audits (pure measurement, merges
        # unchanged); the fleet profile has no per-entry merge to audit
        audit = (eng.attach_audit()
                 if obs.enabled() and not eng.fleet_profile else None)
        with obs.span("scenario.train", sim_t=0.0, scenario=sc.name,
                      trace=trace, seed=seed, mode=mode) as sp:
            runs[mode] = eng.run()
            sp.end_sim(runs[mode].sim_time_s)
        if audit is not None:
            for fl in audit.flags():
                obs.point("audit.flag", scenario=sc.name, mode=mode,
                          cid=fl.cid, metric=fl.metric, z=fl.z)
    return data, runs


def result_row(runs: Dict[str, RunMetrics]) -> Dict[str, float]:
    """The Table-1 relative-improvement row for one baseline/enhanced pair
    (same metric definitions as benchmarks/domains.py)."""
    b, e = runs["baseline"], runs["enhanced"]
    tgt = common_target([b.val_error_curve, e.val_error_curve])
    tb = time_to_error(b.val_error_curve, tgt)
    te = time_to_error(e.val_error_curve, tgt)
    return {
        "time_down": pct_reduction(tb[0], te[0]) if tb and te else 0.0,
        "comm_down": pct_reduction(b.total_bytes, e.total_bytes),
        "msgs_down": pct_reduction(b.n_messages, e.n_messages),
        "conv_down": pct_reduction(tb[1], te[1]) if tb and te else 0.0,
        "acc_delta_pp": 100.0 * (b.final_test_error - e.final_test_error),
        "base_err": b.final_test_error,
        "enh_err": e.final_test_error,
        "base_bytes": float(b.total_bytes),
        "enh_bytes": float(e.total_bytes),
        "unavailable_rounds": float(e.rounds_unavailable),
    }


def replay_serve(sc: Scenario, cluster: ShardCluster, data: Dict,
                 trace: str, seed: int = 0, duration_s: float = 1.5,
                 autoscale: bool = True) -> Dict:
    """Replay the scenario's request trace into the serving fleet.

    Each client emits Poisson requests at ``serve_rate / n_clients``; the
    *same behavior models* that shaped training gate them — a request is
    dropped while the client is unavailable and delayed by its link
    latency.  Serving time runs ``time_warp`` times slower than behavior
    time, so diurnal cycles and outage windows project onto the replay
    window.  Asserts the fleet's zero-loss invariant (every accepted
    request answered exactly once across membership churn)."""
    sp = obs.span("scenario.serve_replay", sim_t=0.0, scenario=sc.name,
                  trace=trace, seed=seed)
    cluster.run_until_quiescent()
    cluster.rebase_clock(0.0)
    server = ShardedEnsembleServer(cluster, SERVE_BATCH,
                                   service_model=_service_model)
    # SLO ledger over the replay: measurement only (the autoscaler keeps
    # its queue/p99 signal — burn-rate pressure is opted into by the
    # sustained_slo benchmark), so scenario bands are unchanged
    monitor = SLOMonitor([SLObjective(tenant=sc.name,
                                      latency_threshold_s=0.05,
                                      target=0.95,
                                      window_s=max(0.25, duration_s / 3.0))])
    server.attach_slo(monitor)
    scaler = (FleetAutoscaler(server, _autoscale_config(len(cluster.hosts)))
              if autoscale else None)

    # request trace from the behavior models (fresh instances: the serve
    # epoch is a different day than training).  Candidate emission times
    # are gated in *global* time order so stateful behaviors — including
    # processes shared across clients, like a site-outage window or the
    # blockchain ledger — see non-decreasing timestamps.
    behavior_for = sc.behavior_for(trace, seed + 101)
    xs = np.asarray(data["test"][0], np.float32)
    rng = np.random.RandomState(seed * 31 + 7)
    per_client = sc.serve_rate / sc.domain.n_clients
    candidates: List[Tuple[float, int]] = []
    for cid in range(sc.domain.n_clients):
        t = 0.0
        while True:
            t += rng.exponential(1.0 / per_client)
            if t >= duration_s:
                break
            candidates.append((t, cid))
    candidates.sort()

    behaviors = ([behavior_for(c) for c in range(sc.domain.n_clients)]
                 if behavior_for is not None else None)
    arrivals: List[Tuple[float, int]] = []
    offline = 0
    for t, cid in candidates:
        if behaviors is None:
            arrivals.append((t, cid))
            continue
        beh = behaviors[cid]
        bt = t * sc.time_warp            # serve-s -> behavior-s
        if not beh.availability(bt):
            offline += 1                 # device offline: nothing sent
            continue
        # query delay is measured in behavior-seconds; project it back
        # onto the serving clock (reads never pay training-commit costs)
        arrivals.append((t + beh.query_delay(bt) / sc.time_warp, cid))
    arrivals.sort()

    # chain mode: kill the committee leader halfway through the replay —
    # an abrupt death, not a drain.  The autoscaler sheds the dead replica
    # (accepted requests reroute), a replacement warms from chain history
    # alone, and the zero-loss assertion below must still hold.
    chain_mode = hasattr(cluster, "chain")
    kill_at = (len(arrivals) // 2
               if chain_mode and scaler is not None else None)
    killed = None
    accepted, rids = 0, []
    for i, (t, cid) in enumerate(arrivals):
        if kill_at is not None and i == kill_at:
            up = cluster.host_ids()
            if len(up) > 1:
                leader = cluster.leader()
                killed = leader if leader in up else up[0]
                cluster.kill(killed)
        ok, out = server.submit(sc.name, xs[rng.randint(xs.shape[0])], t)
        accepted += ok
        rids.extend(r.rid for r in out)
        if scaler is not None:
            rids.extend(r.rid for r in scaler.step(t))
        monitor.check(t)
    rids.extend(r.rid for r in server.drain())
    if len(rids) != accepted or len(set(rids)) != len(rids):
        raise AssertionError(
            f"request loss under churn: accepted={accepted} "
            f"answered={len(rids)} unique={len(set(rids))}")

    rep = server.report()
    tenant = rep["tenants"].get(sc.name, {})
    # settle the alert state past the drain tail before summarizing
    t_end = duration_s + monitor.objectives[sc.name].window_s
    monitor.check(t_end)
    slo_rep = monitor.report(t_end)["tenants"].get(sc.name, {})
    sp.set(completed=rep["completed"], hosts_final=len(server.servers))
    sp.end(sim_t=duration_s)
    return {
        "offered": len(arrivals), "offline_suppressed": offline,
        "completed": rep["completed"], "rejected": rep["rejected"],
        "p50_ms": rep["p50_ms"], "p99_ms": rep["p99_ms"],
        "throughput_rps": rep["throughput_rps"],
        "mean_batch": rep["mean_batch"],
        "cache_hit_rate": rep["cache"]["hit_rate"],
        "snapshot_version": tenant.get("snapshot_version", 0),
        "hosts_final": len(server.servers),
        "scale_outs": scaler.stats.scale_outs if scaler else 0,
        "scale_ins": scaler.stats.scale_ins if scaler else 0,
        "rerouted": scaler.stats.rerouted if scaler else 0,
        "killed_host": killed,
        "slo": {
            "good": slo_rep.get("good", 0),
            "bad": slo_rep.get("bad", 0),
            "budget_remaining": slo_rep.get("budget_remaining", 1.0),
            "alerts_fired": sum(1 for e in monitor.alerts.events
                                if e.kind == "fire"),
            "alerts_active": len(monitor.alerts.active()),
        },
    }


def run_scenario(name_or_scenario, trace: str = "legacy", seed: int = 0,
                 n_rounds: Optional[int] = None, serve: bool = True,
                 serve_duration_s: float = 1.5, hosts: int = 2,
                 autoscale: bool = True, publish_every: int = 2,
                 engine: str = "events") -> ScenarioReport:
    """One scenario end to end: train both modes through ``trace``, check
    the paper band, then (optionally) replay the publish/request trace
    into an autoscaled serving fleet.  Scenarios with
    ``serve_replay=False`` (the fleet-scale smokes) always skip the serve
    phase."""
    sc = (name_or_scenario if isinstance(name_or_scenario, Scenario)
          else get_scenario(name_or_scenario))
    serve = serve and sc.serve_replay
    with obs.span("scenario.run", scenario=sc.name, trace=trace, seed=seed):
        if not serve:
            cluster = None
        elif sc.chain:
            # decentralized chain-of-record mode: publishes commit to the
            # shared chain; hosts (and any replacement the autoscaler
            # warms later) fold confirmed blocks — no central registry
            from repro.chain import ChainCluster
            cluster = ChainCluster(hosts, GossipConfig(seed=seed))
        else:
            cluster = ShardCluster(hosts, GossipConfig(seed=seed))
        data, runs = train_pair(sc, trace, seed=seed, n_rounds=n_rounds,
                                cluster=cluster, publish_every=publish_every,
                                engine=engine)
        row = result_row(runs)
        report = ScenarioReport(
            scenario=sc.name, trace=trace, seed=seed,
            baseline=runs["baseline"], enhanced=runs["enhanced"],
            row=row, band_failures=sc.band.check(row))
        if serve:
            report.serve = replay_serve(sc, cluster, data, trace, seed=seed,
                                        duration_s=serve_duration_s,
                                        autoscale=autoscale)
    return report


def summarize(rep: ScenarioReport) -> str:
    """Human-readable one-scenario summary (the run_scenario CLI output)."""
    sc = get_scenario(rep.scenario)
    lines = [
        f"scenario {rep.scenario} · trace {rep.trace} · seed {rep.seed}",
        f"  train: time_down {rep.row['time_down']:+.1f}%  "
        f"comm_down {rep.row['comm_down']:+.1f}%  "
        f"msgs_down {rep.row['msgs_down']:+.1f}%  "
        f"acc_delta {rep.row['acc_delta_pp']:+.1f}pp  "
        f"(unavailable rounds: {rep.row['unavailable_rounds']:.0f})",
        f"  band:  time ~{sc.band.time_down[0]:.0f}-"
        f"{sc.band.time_down[1]:.0f}%  comm ~{sc.band.comm_down[0]:.0f}-"
        f"{sc.band.comm_down[1]:.0f}%  acc {sc.band.acc_delta_pp[0]:+.1f}.."
        f"{sc.band.acc_delta_pp[1]:+.1f}pp  -> "
        + ("WITHIN BAND" if rep.within_band
           else "OUT OF BAND: " + "; ".join(rep.band_failures)),
    ]
    if rep.serve is not None:
        s = rep.serve
        lines.append(
            f"  serve: {s['completed']} done / {s['rejected']} shed "
            f"(+{s['offline_suppressed']} never sent)  "
            f"p50 {s['p50_ms']:.2f} ms  p99 {s['p99_ms']:.2f} ms  "
            f"cache {s['cache_hit_rate']:.0%}  "
            f"snapshot v{s['snapshot_version']}  "
            f"hosts {s['hosts_final']} "
            f"({s['scale_outs']} out / {s['scale_ins']} in, "
            f"{s['rerouted']} rerouted)")
    return "\n".join(lines)
