"""Client-behavior models: the device/link simulation layer under the
federated engines.

The engines used to model heterogeneity as two i.i.d. scalars
(``straggler_factor``, ``dropout_prob``).  Real deployments are nothing
like that: phone availability follows the day/night cycle, IoT radios
burst between good and terrible (Gilbert-Elliott), hospital sites go down
*together* for maintenance, and blockchain peers pay a block-confirmation
delay on every message.  ASO-Fed (arXiv:1911.02134) and the FLchain
analysis (arXiv:2112.07938) both show that it is exactly this *correlated,
time-varying* behavior that separates async from sync methods — so the
simulator has to produce it.

A :class:`ClientBehavior` answers three questions the engine asks on every
round of one client's life:

* ``availability(t)``   — can the client participate right now?
* ``compute_time(work, t)`` — seconds to do ``work`` nominal seconds of
  compute, starting at ``t``;
* ``link(t)``           — the uplink as a (latency, bandwidth) pair.

plus ``stall_time(work, t)`` — the wall-clock penalty of an unavailable
round (defaults to ``compute_time``, matching the legacy dropout stall).

Timestamps are the engine's simulated clock and must be non-decreasing per
behavior instance (each instance belongs to exactly one client); stateful
models (Gilbert chains, outage processes) advance lazily to ``t``.

:class:`LegacyBehavior` reproduces the scalar model **bit-for-bit**: it
draws from the same RNG stream in the same order and computes the same
float expressions, so an engine constructed without an explicit
``behavior_for`` is unchanged down to the last bit at equal seeds.
"""
from __future__ import annotations

import bisect
import json
import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np


@dataclass(frozen=True)
class Link:
    """One uplink observation: fixed latency + available bandwidth."""
    latency_s: float
    bandwidth_mbps: float

    def tx_time(self, nbytes: int) -> float:
        """Seconds to push ``nbytes`` through this link (engine cost model)."""
        return nbytes / (self.bandwidth_mbps / 8.0 * 1e6) + self.latency_s


class ClientBehavior:
    """Per-client device/link model driving the engines' cost simulation."""

    def availability(self, t: float) -> bool:
        """Can the client train/sync at simulated time ``t``?  May consume
        randomness; the engine calls it exactly once per round."""
        return True

    def compute_time(self, work: float, t: float = 0.0) -> float:
        """Seconds to perform ``work`` nominal seconds of compute at ``t``."""
        return work

    def link(self, t: float) -> Link:
        """The client's uplink at ``t``."""
        return Link(0.05, 10.0)

    def stall_time(self, work: float, t: float = 0.0) -> float:
        """Wall-clock penalty of an unavailable round.  The legacy model
        charges one extra compute round; outage models wait the window out."""
        return self.compute_time(work, t)

    def query_delay(self, t: float) -> float:
        """Extra delay a serving *query* pays on this client's link at
        ``t`` — the link latency by default.  Models where training
        uplinks pay costs queries do not (a blockchain commit waits for
        inclusion; a read does not) override this."""
        return self.link(t).latency_s


# --------------------------------------------------------------- legacy shim
class LegacyBehavior(ClientBehavior):
    """The pre-simulator scalar model as a behavior.

    Bit-for-bit contract: ``availability`` consumes exactly one
    ``rng.rand()`` (the old per-round dropout draw), ``compute_time``
    computes ``work * speed`` (the old ``BASE_ROUND_S * c.speed``), and
    ``link`` is the constant (``LATENCY_S``, ``cfg.link_mbps``) pair —
    identical draws in identical order, identical float expressions.
    """

    def __init__(self, speed: float, dropout_prob: float, link_mbps: float,
                 latency_s: float, rng: np.random.RandomState):
        self.speed = float(speed)
        self.dropout_prob = float(dropout_prob)
        self._link = Link(float(latency_s), float(link_mbps))
        self.rng = rng

    def availability(self, t: float) -> bool:
        return not (self.rng.rand() < self.dropout_prob)

    def compute_time(self, work: float, t: float = 0.0) -> float:
        return work * self.speed

    def link(self, t: float) -> Link:
        return self._link


def legacy_behaviors(cfg, n: int, rng: np.random.RandomState,
                     latency_s: float = 0.05) -> List[LegacyBehavior]:
    """The engine's default: one :class:`LegacyBehavior` per client with
    speeds drawn log-uniform in ``[1, straggler_factor]`` — the exact
    vectorized draw (and therefore RNG stream position) the engine used
    before behaviors existed.  All clients share ``rng`` so the per-round
    availability draws interleave in the legacy order too."""
    speeds = np.exp(rng.uniform(0.0, math.log(cfg.straggler_factor), size=n))
    return [LegacyBehavior(float(speeds[i]), cfg.dropout_prob, cfg.link_mbps,
                           latency_s, rng) for i in range(n)]


# ------------------------------------------------------------ mobile diurnal
class DiurnalBehavior(ClientBehavior):
    """Phone-style day/night cycle: availability, compute speed, and link
    bandwidth all follow a sinusoidal daylight curve (plus a battery duty
    cycle — the device naps when "charging overnight" is over and the
    battery saver kicks in, modeled by the trough availability).

    ``daylight(t)`` in [0, 1]; availability is a Bernoulli draw with
    probability interpolated between ``trough`` and ``peak``; compute slows
    by up to ``night_slowdown`` at full night; bandwidth scales between 60%
    and 100% of nominal with daylight (congested evening cells).
    """

    def __init__(self, speed: float, period_s: float, phase_s: float,
                 rng: np.random.RandomState, *, peak: float = 0.95,
                 trough: float = 0.35, night_slowdown: float = 1.5,
                 link_mbps: float = 5.0, latency_s: float = 0.05):
        assert 0.0 <= trough <= peak <= 1.0
        self.speed = float(speed)
        self.period_s = float(period_s)
        self.phase_s = float(phase_s)
        self.peak, self.trough = float(peak), float(trough)
        self.night_slowdown = float(night_slowdown)
        self.link_mbps, self.latency_s = float(link_mbps), float(latency_s)
        self.rng = rng

    def daylight(self, t: float) -> float:
        return 0.5 * (1.0 + math.sin(
            2.0 * math.pi * (t + self.phase_s) / self.period_s))

    def availability(self, t: float) -> bool:
        p = self.trough + (self.peak - self.trough) * self.daylight(t)
        return self.rng.rand() < p

    def compute_time(self, work: float, t: float = 0.0) -> float:
        slow = 1.0 + self.night_slowdown * (1.0 - self.daylight(t))
        return work * self.speed * slow

    def link(self, t: float) -> Link:
        scale = 0.6 + 0.4 * self.daylight(t)
        return Link(self.latency_s, self.link_mbps * scale)


# ----------------------------------------------------------- IoT bursty link
class GilbertLinkBehavior(ClientBehavior):
    """Gilbert-Elliott two-state radio: the link alternates between a good
    and a bad state with exponential sojourn times.  In the bad state the
    bandwidth collapses, latency spikes, and rounds are lost with
    ``drop_in_bad`` probability (deep fade = the legacy dropout, but bursty
    and autocorrelated instead of i.i.d.)."""

    def __init__(self, speed: float, rng: np.random.RandomState, *,
                 mean_good_s: float = 8.0, mean_bad_s: float = 2.0,
                 good: Link = Link(0.05, 1.0), bad: Link = Link(0.5, 0.05),
                 drop_in_bad: float = 0.6, drop_in_good: float = 0.02):
        self.speed = float(speed)
        self.rng = rng
        self.mean_good_s, self.mean_bad_s = float(mean_good_s), float(mean_bad_s)
        self.good, self.bad = good, bad
        self.drop_in_bad = float(drop_in_bad)
        self.drop_in_good = float(drop_in_good)
        self._good_now = True
        self._until = float(rng.exponential(self.mean_good_s))

    def _advance(self, t: float) -> None:
        while t >= self._until:
            self._good_now = not self._good_now
            mean = self.mean_good_s if self._good_now else self.mean_bad_s
            self._until += float(self.rng.exponential(mean))

    def in_good_state(self, t: float) -> bool:
        self._advance(t)
        return self._good_now

    def availability(self, t: float) -> bool:
        drop = (self.drop_in_good if self.in_good_state(t)
                else self.drop_in_bad)
        return not (self.rng.rand() < drop)

    def compute_time(self, work: float, t: float = 0.0) -> float:
        return work * self.speed

    def link(self, t: float) -> Link:
        return self.good if self.in_good_state(t) else self.bad


# ------------------------------------------------- correlated site outages
class SiteOutageProcess:
    """A shared outage process for one *site* (an edge rack, a hospital
    wing): Poisson outage arrivals with exponential durations, sampled
    lazily.  Every client attached to the site observes the *same* windows
    — the correlated multi-client failure the i.i.d. scalar model cannot
    produce."""

    def __init__(self, rng: np.random.RandomState, *,
                 mean_up_s: float = 20.0, mean_down_s: float = 4.0):
        self.rng = rng
        self.mean_up_s, self.mean_down_s = float(mean_up_s), float(mean_down_s)
        self._windows: List[tuple] = []       # (start, end), ascending
        self._starts: List[float] = []        # parallel starts for bisect
        self._horizon = 0.0                   # sampled up to here

    def _extend(self, t: float) -> None:
        while self._horizon <= t:
            start = self._horizon + float(self.rng.exponential(self.mean_up_s))
            end = start + float(self.rng.exponential(self.mean_down_s))
            self._windows.append((start, end))
            self._starts.append(start)
            self._horizon = end

    def _window_at(self, t: float):
        self._extend(t)
        i = bisect.bisect_right(self._starts, t) - 1
        if i >= 0 and self._windows[i][0] <= t < self._windows[i][1]:
            return self._windows[i]
        return None

    def down(self, t: float) -> bool:
        return self._window_at(t) is not None

    def remaining(self, t: float) -> float:
        """Seconds until the current outage (if any) ends."""
        w = self._window_at(t)
        return w[1] - t if w is not None else 0.0


class SiteBehavior(ClientBehavior):
    """A client pinned to a :class:`SiteOutageProcess`: unavailable exactly
    while its site is down, and an unavailable round stalls until the
    outage clears (maintenance windows are waited out, not retried)."""

    def __init__(self, site: SiteOutageProcess, speed: float, *,
                 link_mbps: float = 10.0, latency_s: float = 0.05):
        self.site = site
        self.speed = float(speed)
        self._link = Link(float(latency_s), float(link_mbps))

    def availability(self, t: float) -> bool:
        return not self.site.down(t)

    def compute_time(self, work: float, t: float = 0.0) -> float:
        return work * self.speed

    def link(self, t: float) -> Link:
        return self._link

    def stall_time(self, work: float, t: float = 0.0) -> float:
        return max(self.site.remaining(t), self.compute_time(work, t))


# ------------------------------------------------------- blockchain confirm
class BlockchainLedger:
    """The *shared* chain every peer commits through: one message per
    block slot.  This is what actually separates sync from async on a
    chain (the FLchain analysis, arXiv:2112.07938): a synchronous round
    dumps K commits at once and the K-th waits ~K block intervals for
    inclusion, while the async method's sparse syncs usually find the next
    block free.  ``commit(t)`` reserves the next free slot at or after
    ``t`` and returns the inclusion wait."""

    def __init__(self, rng: np.random.RandomState, *,
                 block_interval_s: float = 0.4,
                 commits_per_block: int = 1,
                 prune_every: int = 64):
        self.rng = rng
        self.block_interval_s = float(block_interval_s)
        self.gap = self.block_interval_s / max(1, int(commits_per_block))
        self._slots: List[float] = []    # reserved slot times, ascending
        # slot pruning: committers register a *cursor* and stamp every
        # commit with it.  Per-cursor times are non-decreasing (the
        # ClientBehavior timestamp contract), so min(cursors) is the
        # earliest time any future commit can carry — reserved slots
        # more than ``gap`` older can never collide again and are
        # dropped every ``prune_every`` commits.  Cursor-less commits
        # keep the conservative unbounded behavior (no cursor floor ->
        # no pruning), so mixed callers stay exact.
        self.prune_every = int(prune_every)
        self._cursors: List[float] = []
        self._untracked = False          # any commit ever made cursor-less
        self._since_prune = 0
        self.pruned_slots = 0

    def register(self) -> int:
        """Register one committer; returns the cursor to pass to
        :meth:`commit`.  Pruning only engages when *every* commit on this
        ledger is cursor-stamped."""
        self._cursors.append(float("-inf"))
        return len(self._cursors) - 1

    @property
    def live_slots(self) -> int:
        return len(self._slots)

    def commit(self, t: float, cursor: Optional[int] = None) -> float:
        """Seconds from ``t`` until this message's block is mined."""
        # residual wait to the next block (Poisson arrivals), then the
        # first slot >= ``gap`` away from every reserved one.  Slots are
        # kept sorted and searched by *simulated* time, so callers need
        # not commit in time order (the enhanced engine advances clients
        # one at a time — an early-clock commit issued late must not
        # queue behind later-clock slots it precedes on chain).
        if cursor is None:
            self._untracked = True
        else:
            self._cursors[cursor] = max(self._cursors[cursor], float(t))
        earliest = t + float(self.rng.exponential(self.block_interval_s))
        slot = earliest
        i = bisect.bisect_left(self._slots, slot - self.gap)
        while i < len(self._slots) and self._slots[i] < slot + self.gap:
            slot = max(slot, self._slots[i] + self.gap)
            i += 1
        bisect.insort(self._slots, slot)
        self._since_prune += 1
        if self._since_prune >= self.prune_every:
            self._since_prune = 0
            self._prune()
        return slot - t

    def _prune(self) -> None:
        if self._untracked or not self._cursors:
            return
        floor = min(self._cursors)
        if floor == float("-inf"):
            return
        # a future commit at t >= floor only scans slots >= t - gap; any
        # slot strictly below floor - gap is unreachable forever
        cut = bisect.bisect_left(self._slots, floor - self.gap)
        if cut:
            self.pruned_slots += cut
            del self._slots[:cut]


class BlockDelayBehavior(ClientBehavior):
    """Blockchain peer: every message waits for block inclusion plus
    ``confirmations - 1`` further blocks.  With a shared
    :class:`BlockchainLedger` the inclusion wait queues on chain capacity
    (commits serialize — the correlated cost the i.i.d. model misses);
    without one, the residual wait is i.i.d. exponential.  Congestion
    occasionally bumps a message by a few extra blocks (fee-market
    spikes)."""

    def __init__(self, speed: float, rng: np.random.RandomState, *,
                 block_interval_s: float = 0.6, confirmations: int = 2,
                 congestion_prob: float = 0.1, congestion_blocks: int = 3,
                 link_mbps: float = 2.0, latency_s: float = 0.05,
                 fork_drop: float = 0.02,
                 ledger: Optional[BlockchainLedger] = None):
        self.speed = float(speed)
        self.rng = rng
        self.block_interval_s = float(block_interval_s)
        self.confirmations = int(confirmations)
        self.congestion_prob = float(congestion_prob)
        self.congestion_blocks = int(congestion_blocks)
        self.link_mbps, self.latency_s = float(link_mbps), float(latency_s)
        self.fork_drop = float(fork_drop)
        self.ledger = ledger
        # per-behavior timestamps are non-decreasing, so each client
        # registers a ledger cursor — the shared ledger prunes slots no
        # live client can collide with (bounded memory at fleet scale)
        self._cursor = ledger.register() if ledger is not None else None

    def availability(self, t: float) -> bool:
        # a fork orphans the round's message: the legacy dropout analogue
        return not (self.rng.rand() < self.fork_drop)

    def compute_time(self, work: float, t: float = 0.0) -> float:
        return work * self.speed

    def link(self, t: float) -> Link:
        if self.ledger is not None:
            wait = self.ledger.commit(t, cursor=self._cursor)
        else:
            wait = float(self.rng.exponential(self.block_interval_s))
        wait += (self.confirmations - 1) * self.block_interval_s
        if self.rng.rand() < self.congestion_prob:
            wait += self.congestion_blocks * self.block_interval_s
        return Link(self.latency_s + wait, self.link_mbps)

    def query_delay(self, t: float) -> float:
        # serving reads see the latest *confirmed* state — they neither
        # reserve a ledger slot nor wait for inclusion
        return self.latency_s


# ------------------------------------------------------------ trace replay
_TRACE_FIELDS = ("available", "speed", "latency_s", "bandwidth_mbps")


class TraceSchedule(ClientBehavior):
    """Piecewise-constant behavior from a recorded trace, optionally
    layered over a ``base`` behavior.

    A trace is a list of segments ``{"t": start, ...fields}``, sorted by
    ``t``; each segment holds any subset of ``available`` (bool, ANDed with
    the base), ``speed`` (multiplier on the base compute time), and
    ``latency_s``/``bandwidth_mbps`` (overriding the base link).  With
    ``loop_s`` set the trace repeats with that period — a recorded day
    replays forever — and ``phase_s`` rotates the cycle (stagger one
    recorded trace across a fleet without rewriting its segments); before
    the first segment a looped trace continues its last segment (cyclic),
    a one-shot trace clamps to its first.  ``from_json``/``to_json``
    round-trip the schedule, so measured deployments drop straight into
    the scenario registry."""

    def __init__(self, segments: Sequence[Dict], *,
                 base: Optional[ClientBehavior] = None,
                 loop_s: Optional[float] = None, phase_s: float = 0.0):
        segs = sorted((dict(s) for s in segments), key=lambda s: s["t"])
        if not segs:
            raise ValueError("TraceSchedule needs at least one segment")
        for s in segs:
            unknown = set(s) - {"t"} - set(_TRACE_FIELDS)
            if unknown:
                raise ValueError(f"unknown trace fields {sorted(unknown)}")
        self.segments = segs
        self._starts = [s["t"] for s in segs]
        self.base = base or ClientBehavior()
        self.loop_s = None if loop_s is None else float(loop_s)
        self.phase_s = float(phase_s)

    def _segment(self, t: float) -> Dict:
        t += self.phase_s
        if self.loop_s is not None:
            t = t % self.loop_s
        i = bisect.bisect_right(self._starts, t) - 1
        if i < 0:
            # before the first start: a cycle is mid-way through its last
            # segment; a one-shot trace hasn't begun — clamp to the first
            return self.segments[-1 if self.loop_s is not None else 0]
        return self.segments[i]

    def availability(self, t: float) -> bool:
        ok = self._segment(t).get("available", True)
        # base consulted second: its RNG draw only happens while the trace
        # says the device is on at all (an off phone draws nothing)
        return bool(ok) and self.base.availability(t)

    def compute_time(self, work: float, t: float = 0.0) -> float:
        return self.base.compute_time(work, t) * float(
            self._segment(t).get("speed", 1.0))

    def link(self, t: float) -> Link:
        seg, base = self._segment(t), self.base.link(t)
        return Link(float(seg.get("latency_s", base.latency_s)),
                    float(seg.get("bandwidth_mbps", base.bandwidth_mbps)))

    # --------------------------------------------------------------- JSON
    def to_json(self) -> Dict:
        out: Dict = {"segments": [dict(s) for s in self.segments]}
        if self.loop_s is not None:
            out["loop_s"] = self.loop_s
        if self.phase_s:
            out["phase_s"] = self.phase_s
        return out

    @classmethod
    def from_json(cls, obj, *, base: Optional[ClientBehavior] = None,
                  phase_s: float = 0.0) -> "TraceSchedule":
        """Build from a dict (``{"segments": [...], "loop_s": ...}``), a
        bare segment list, or a JSON string of either."""
        if isinstance(obj, str):
            obj = json.loads(obj)
        if isinstance(obj, list):
            obj = {"segments": obj}
        return cls(obj["segments"], base=base, loop_s=obj.get("loop_s"),
                   phase_s=obj.get("phase_s", phase_s))

    @classmethod
    def from_file(cls, path, *, base: Optional[ClientBehavior] = None
                  ) -> "TraceSchedule":
        with open(path) as f:
            return cls.from_json(json.load(f), base=base)
